//! Line-delimited JSON protocol over the search service.
//!
//! One request per line, one response per line; every response carries
//! `"ok"`. The dispatcher is transport-agnostic (the TCP server and the
//! in-process tests share it).
//!
//! ```text
//! → {"op":"open","env":"Breakout","seed":7,"sims":64}
//! ← {"ok":true,"session":1}
//! → {"op":"think","session":1}
//! ← {"ok":true,"action":2,"value":0.41,"sims":64,"tree":91,"ms":5.2,"quiescent":true}
//! → {"op":"advance","session":1,"action":2}
//! ← {"ok":true,"reward":1.0,"done":false,"reused":true,"retained":17,"steps":1}
//! → {"op":"close","session":1}
//! ← {"ok":true,"thinks":1,"sims":64,"steps":1,"unobserved":0}
//! ```
//!
//! Also: `best` (read the recommendation without searching), `metrics`
//! (service snapshot) and `ping`.

use anyhow::{anyhow, bail, Result};

use crate::env::tapgame::{Level, TapGame};
use crate::env::{atari, garnet::Garnet, Env};
use crate::mcts::common::SearchSpec;
use crate::service::json::{obj, Json};
use crate::service::metrics::ServiceMetrics;
use crate::service::scheduler::{ServiceHandle, SessionOptions};

/// Side effect of a dispatched line, for connection-scoped session
/// tracking (the TCP server closes a connection's leftover sessions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineEffect {
    None,
    Opened(u64),
    Closed(u64),
}

/// Build an environment by protocol name: the 15 Atari-like suite games,
/// `level-35` / `level-58` (tap game), or `garnet` (the cheap random MDP,
/// handy for load tests).
pub fn make_env(name: &str, seed: u64) -> Result<Box<dyn Env>> {
    match name {
        "level-35" => Ok(Box::new(TapGame::new(Level::level35(), seed))),
        "level-58" => Ok(Box::new(TapGame::new(Level::level58(), seed))),
        "garnet" => Ok(Box::new(Garnet::new(15, 3, 30, 0.0, seed))),
        other if atari::GAMES.contains(&other) => Ok(atari::make(other, seed)),
        other => bail!(
            "unknown env {other:?}; expected one of the Atari suite, level-35, level-58, garnet"
        ),
    }
}

/// Spec defaults by environment family, with per-field overrides from the
/// request object.
fn spec_from(req: &Json, env_name: &str) -> Result<SearchSpec> {
    let mut spec = if env_name.starts_with("level-") {
        SearchSpec::tap_game()
    } else {
        SearchSpec::default()
    };
    spec.seed = field_u64(req, "seed")?.unwrap_or(0);
    if let Some(v) = field_u32(req, "sims")? {
        spec.max_simulations = v;
    }
    if let Some(v) = field_u32(req, "rollout")? {
        spec.rollout_limit = v;
    }
    if let Some(v) = field_u32(req, "depth")? {
        spec.max_depth = v;
    }
    if let Some(v) = field_u32(req, "width")? {
        spec.max_width = v as usize;
    }
    if let Some(v) = field_f64(req, "gamma")? {
        spec.gamma = v;
    }
    Ok(spec)
}

fn field_u64(req: &Json, key: &str) -> Result<Option<u64>> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(
            v.as_u64()
                .ok_or_else(|| anyhow!("field {key:?} must be a non-negative integer"))?,
        )),
    }
}

/// Like [`field_u64`] but rejects values past `u32::MAX` instead of
/// letting a cast silently wrap a client's typo into a tiny budget.
fn field_u32(req: &Json, key: &str) -> Result<Option<u32>> {
    match field_u64(req, key)? {
        None => Ok(None),
        Some(v) => Ok(Some(u32::try_from(v).map_err(|_| {
            anyhow!("field {key:?} out of range (max {})", u32::MAX)
        })?)),
    }
}

fn field_f64(req: &Json, key: &str) -> Result<Option<f64>> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(
            v.as_f64().ok_or_else(|| anyhow!("field {key:?} must be a number"))?,
        )),
    }
}

fn required_u64(req: &Json, key: &str) -> Result<u64> {
    field_u64(req, key)?.ok_or_else(|| anyhow!("missing field {key:?}"))
}

fn error_line(msg: &str) -> String {
    obj([("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))]).render()
}

/// Dispatch one request line; always returns a single response line
/// (without the trailing newline).
pub fn handle_line(handle: &ServiceHandle, line: &str) -> (String, LineEffect) {
    match dispatch(handle, line) {
        Ok((json, effect)) => (json.render(), effect),
        Err(e) => (error_line(&format!("{e:#}")), LineEffect::None),
    }
}

fn dispatch(handle: &ServiceHandle, line: &str) -> Result<(Json, LineEffect)> {
    let req = Json::parse(line)?;
    let op = req
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing field \"op\""))?;
    match op {
        "ping" => Ok((obj([("ok", Json::Bool(true))]), LineEffect::None)),
        "open" => {
            let env_name = req.get("env").and_then(|v| v.as_str()).unwrap_or("Breakout");
            let seed = field_u64(&req, "seed")?.unwrap_or(0);
            let env = make_env(env_name, seed)?;
            let spec = spec_from(&req, env_name)?;
            let opts = SessionOptions {
                think_sims: 0,
                weight: field_f64(&req, "weight")?.unwrap_or(1.0),
                total_sim_budget: field_u64(&req, "budget")?,
            };
            let sid = handle.open(env, spec, opts)?;
            Ok((
                obj([("ok", Json::Bool(true)), ("session", Json::Num(sid as f64))]),
                LineEffect::Opened(sid),
            ))
        }
        "think" => {
            let sid = required_u64(&req, "session")?;
            let sims = field_u32(&req, "sims")?.unwrap_or(0);
            let t = handle.think(sid, sims)?;
            let mut fields = vec![
                ("ok".to_string(), Json::Bool(true)),
                ("action".to_string(), Json::Num(t.action as f64)),
                ("value".to_string(), Json::Num(t.value)),
                ("sims".to_string(), Json::Num(t.sims as f64)),
                ("tree".to_string(), Json::Num(t.tree_size as f64)),
                ("ms".to_string(), Json::Num(t.elapsed_ms)),
                ("quiescent".to_string(), Json::Bool(t.quiescent)),
            ];
            if let Some(rem) = t.remaining {
                fields.push(("remaining".to_string(), Json::Num(rem as f64)));
            }
            Ok((Json::Obj(fields), LineEffect::None))
        }
        "advance" => {
            let sid = required_u64(&req, "session")?;
            let action = required_u64(&req, "action")? as usize;
            let a = handle.advance(sid, action)?;
            Ok((
                obj([
                    ("ok", Json::Bool(true)),
                    ("reward", Json::Num(a.reward)),
                    ("done", Json::Bool(a.done)),
                    ("reused", Json::Bool(a.reused)),
                    ("retained", Json::Num(a.retained as f64)),
                    ("steps", Json::Num(a.steps as f64)),
                ]),
                LineEffect::None,
            ))
        }
        "best" => {
            let sid = required_u64(&req, "session")?;
            let action = handle.best_action(sid)?;
            Ok((
                obj([("ok", Json::Bool(true)), ("action", Json::Num(action as f64))]),
                LineEffect::None,
            ))
        }
        "close" => {
            let sid = required_u64(&req, "session")?;
            let c = handle.close(sid)?;
            Ok((
                obj([
                    ("ok", Json::Bool(true)),
                    ("thinks", Json::Num(c.thinks as f64)),
                    ("sims", Json::Num(c.sims as f64)),
                    ("steps", Json::Num(c.steps as f64)),
                    ("unobserved", Json::Num(c.unobserved as f64)),
                ]),
                LineEffect::Closed(sid),
            ))
        }
        "metrics" => {
            let m = handle.metrics()?;
            Ok((metrics_json(&m), LineEffect::None))
        }
        other => bail!("unknown op {other:?}"),
    }
}

/// Render a metrics snapshot as the `metrics` response object.
pub fn metrics_json(m: &ServiceMetrics) -> Json {
    obj([
        ("ok", Json::Bool(true)),
        ("uptime_s", Json::Num(m.uptime.as_secs_f64())),
        ("sessions_open", Json::Num(m.sessions_open as f64)),
        ("sessions_opened", Json::Num(m.sessions_opened as f64)),
        ("sessions_closed", Json::Num(m.sessions_closed as f64)),
        ("thinks", Json::Num(m.thinks as f64)),
        ("sims", Json::Num(m.sims as f64)),
        ("sessions_per_sec", Json::Num(m.sessions_per_sec)),
        ("thinks_per_sec", Json::Num(m.thinks_per_sec)),
        ("sims_per_sec", Json::Num(m.sims_per_sec)),
        ("think_ms_mean", Json::Num(m.think_ms_mean)),
        ("think_ms_p50", Json::Num(m.think_ms_p50)),
        ("think_ms_p90", Json::Num(m.think_ms_p90)),
        ("think_ms_p99", Json::Num(m.think_ms_p99)),
        ("exp_occupancy", Json::Num(m.exp_occupancy)),
        ("sim_occupancy", Json::Num(m.sim_occupancy)),
        ("expansion_workers", Json::Num(m.expansion_workers as f64)),
        ("simulation_workers", Json::Num(m.simulation_workers as f64)),
        ("pending_expansions", Json::Num(m.pending_expansions as f64)),
        ("pending_simulations", Json::Num(m.pending_simulations as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::scheduler::{SearchService, ServiceConfig};

    fn service() -> SearchService {
        SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        })
    }

    fn ok_field(line: &str) -> Json {
        let v = Json::parse(line).expect("response is valid json");
        assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(true), "line: {line}");
        v
    }

    #[test]
    fn full_episode_over_the_protocol() {
        let svc = service();
        let h = svc.handle();
        let (line, effect) =
            handle_line(&h, r#"{"op":"open","env":"garnet","seed":3,"sims":12,"rollout":8}"#);
        let v = ok_field(&line);
        let sid = v.get("session").unwrap().as_u64().unwrap();
        assert_eq!(effect, LineEffect::Opened(sid));

        let (line, _) = handle_line(&h, &format!(r#"{{"op":"think","session":{sid}}}"#));
        let t = ok_field(&line);
        assert_eq!(t.get("sims").unwrap().as_u64(), Some(12));
        assert_eq!(t.get("quiescent").unwrap().as_bool(), Some(true));
        let action = t.get("action").unwrap().as_u64().unwrap();

        let (line, _) = handle_line(
            &h,
            &format!(r#"{{"op":"advance","session":{sid},"action":{action}}}"#),
        );
        let a = ok_field(&line);
        assert_eq!(a.get("steps").unwrap().as_u64(), Some(1));

        let (line, _) = handle_line(&h, &format!(r#"{{"op":"best","session":{sid}}}"#));
        ok_field(&line);

        let (line, effect) = handle_line(&h, &format!(r#"{{"op":"close","session":{sid}}}"#));
        let c = ok_field(&line);
        assert_eq!(c.get("unobserved").unwrap().as_u64(), Some(0));
        assert_eq!(effect, LineEffect::Closed(sid));
    }

    #[test]
    fn metrics_and_ping() {
        let svc = service();
        let h = svc.handle();
        let (line, _) = handle_line(&h, r#"{"op":"ping"}"#);
        ok_field(&line);
        let (line, _) = handle_line(&h, r#"{"op":"metrics"}"#);
        let m = ok_field(&line);
        assert_eq!(m.get("sessions_open").unwrap().as_u64(), Some(0));
        assert_eq!(m.get("simulation_workers").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn errors_are_reported_not_fatal() {
        let svc = service();
        let h = svc.handle();
        for bad in [
            "not json at all",
            r#"{"no_op":1}"#,
            r#"{"op":"launch"}"#,
            r#"{"op":"think"}"#,
            r#"{"op":"think","session":999}"#,
            r#"{"op":"open","env":"DoesNotExist"}"#,
            r#"{"op":"advance","session":1,"action":-2}"#,
            r#"{"op":"open","env":"garnet","sims":4294967296}"#,
        ] {
            let (line, effect) = handle_line(&h, bad);
            let v = Json::parse(&line).expect("error responses are json");
            assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(false), "input: {bad}");
            assert!(v.get("error").is_some());
            assert_eq!(effect, LineEffect::None);
        }
        // The service must still be alive afterwards.
        let (line, _) = handle_line(&h, r#"{"op":"ping"}"#);
        ok_field(&line);
    }

    #[test]
    fn make_env_names() {
        assert!(make_env("Breakout", 1).is_ok());
        assert!(make_env("level-35", 1).is_ok());
        assert!(make_env("garnet", 1).is_ok());
        assert!(make_env("Pong", 1).is_err(), "not in the synthetic suite");
    }

    #[test]
    fn tap_levels_get_tap_spec_defaults() {
        let req = Json::parse(r#"{"op":"open","env":"level-35"}"#).unwrap();
        let spec = spec_from(&req, "level-35").unwrap();
        assert_eq!(spec.max_depth, 10);
        assert_eq!(spec.max_width, 5);
        let spec = spec_from(&req, "Breakout").unwrap();
        assert_eq!(spec.max_width, 20);
    }
}
