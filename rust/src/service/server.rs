//! TCP front-end: line-delimited JSON (or the binary frame protocol —
//! [`crate::service::frame`]) over a socket, every connection multiplexed
//! onto one [`SessionApi`] handle — a single-shard
//! [`crate::service::ServiceHandle`], the sharded
//! [`crate::service::ShardedHandle`] (`wu-uct serve` / `wu-uct
//! shard-host`) or the cross-process router
//! ([`crate::service::RouterHandle`], `wu-uct serve --hosts ...`)
//! interchangeably — the router's proxied ops travel over pooled
//! [`crate::service::client::HostClient`] connections to its hosts.
//!
//! The data plane is the readiness-based event loop in
//! [`crate::service::evloop`]: a small fixed reactor pool owns every
//! socket (non-blocking reads/writes, partial-line and partial-frame
//! reassembly, write backpressure), and an adaptive dispatch pool runs
//! the blocking session ops. A connection's first byte picks its
//! protocol: [`crate::service::frame::MAGIC`] routes it to the binary
//! framing, anything else to line JSON. The old thread-per-connection
//! model survives as [`TcpServer::bind_threaded`] — the measured baseline
//! `service_throughput` compares the event loop against.
//!
//! Connection hygiene: sessions opened over a connection and not closed
//! by the client are closed automatically when the connection drops, so
//! a crashed load generator cannot leak sessions into the schedulers.
//! Router-assigned opens (the `open` op's explicit `id` field) are the
//! deliberate exception: they belong to the routing tier, whose pooled
//! connections come and go without implying anything about session
//! lifetime. A crashed *end client* still reaps through the router: the
//! router's own front-end tracks that client's sessions and closes them
//! remotely.
//! (On a durable deployment that close is logged to the WAL like any
//! other, so reaped sessions stay gone across restarts.) Lines are read
//! as raw bytes and dispatched through [`handle_bytes`], so even invalid
//! UTF-8 earns an error reply instead of a dropped connection. The op
//! set — open/think/advance/best/close/migrate/metrics/ping — is
//! documented in [`crate::service::proto`].
//!
//! Admission is bounded by [`TcpServer::bind_with_limit`] (`wu-uct serve
//! --max-conns`): past the cap, a new connection is shed at accept with
//! one typed `{"ok":false,"busy":true,...}` line — the same backpressure
//! marker admission-control rejections use, so clients already know to
//! back off and retry — and then closed. Accounting lives in
//! process-wide counters ([`connection_stats`]): an active-connections
//! gauge, a shed counter and a handler-panic counter (a panicking
//! handler still releases its connection slot via RAII and is counted,
//! never silent).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::service::evloop::EventLoop;
use crate::service::proto::{handle_bytes, LineEffect};
use crate::service::SessionApi;

/// Process-wide connection accounting, readable by every metrics path
/// (the `metrics` op and the Prometheus scrape) regardless of which
/// server instance owns a given socket. Enforcement of a `--max-conns`
/// cap is per-server (each accept loop tracks its own slots); these
/// statics are the observability roll-up.
static ACTIVE_CONNECTIONS: AtomicUsize = AtomicUsize::new(0);
static CONNECTIONS_SHED: AtomicU64 = AtomicU64::new(0);
pub(crate) static HANDLER_PANICS: AtomicU64 = AtomicU64::new(0);

/// `(active, shed, panics)` across every [`TcpServer`] in this process.
pub fn connection_stats() -> (usize, u64, u64) {
    (
        ACTIVE_CONNECTIONS.load(Ordering::Relaxed),
        CONNECTIONS_SHED.load(Ordering::Relaxed),
        HANDLER_PANICS.load(Ordering::Relaxed),
    )
}

/// RAII accounting for one served connection. The per-server slot is
/// reserved on the accept thread (so a burst cannot overshoot the cap by
/// racing handler startup); `adopt` takes ownership of that reservation
/// and adds the process-wide gauge. `Drop` runs even when a connection
/// thread panics — the slot is always released, and the panic counted.
/// (On the event loop, panics are caught in the dispatch worker and
/// counted there; the guard rides the connection's terminal reap job.)
pub(crate) struct ConnGuard {
    active: Arc<AtomicUsize>,
}

impl ConnGuard {
    pub(crate) fn adopt(active: Arc<AtomicUsize>) -> ConnGuard {
        ACTIVE_CONNECTIONS.fetch_add(1, Ordering::Relaxed);
        ConnGuard { active }
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
        ACTIVE_CONNECTIONS.fetch_sub(1, Ordering::Relaxed);
        if std::thread::panicking() {
            HANDLER_PANICS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Refuse one connection at the cap: write the typed busy line protocol
/// clients already understand (`busy:true` — back off, retry later) and
/// close. Counted in [`connection_stats`].
fn shed_connection(mut stream: TcpStream) {
    CONNECTIONS_SHED.fetch_add(1, Ordering::Relaxed);
    let _ = stream.write_all(
        b"{\"ok\":false,\"busy\":true,\"error\":\"server at connection capacity; retry later\"}\n",
    );
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// A running TCP front-end; dropping stops the accept loop (and, on the
/// event-loop backend, the reactors — live connections are closed and
/// their sessions reaped).
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    // Held so the reactors outlive the accept loop; the accept thread
    // holds the other reference. None on the threaded backend.
    evloop: Option<Arc<EventLoop>>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `handle`
    /// over the event loop with no connection cap.
    pub fn bind<H: SessionApi>(handle: H, addr: &str) -> Result<TcpServer> {
        TcpServer::bind_with_limit(handle, addr, None)
    }

    /// Like [`TcpServer::bind`] with an optional cap on concurrently
    /// served connections. At the cap, a new connection is shed at
    /// accept ([`shed_connection`]): one typed busy line, then close —
    /// never an unbounded pile-up.
    pub fn bind_with_limit<H: SessionApi>(
        handle: H,
        addr: &str,
        max_conns: Option<usize>,
    ) -> Result<TcpServer> {
        let evloop =
            Arc::new(EventLoop::start(handle).context("starting the event-loop reactors")?);
        let ev = Arc::clone(&evloop);
        let mut server = TcpServer::accept_loop(addr, max_conns, move |stream, guard| {
            ev.register(stream, guard);
        })?;
        server.evloop = Some(evloop);
        Ok(server)
    }

    /// The legacy thread-per-connection backend, kept as the measured
    /// baseline for `service_throughput`'s front-end comparison (and as
    /// a fallback should a platform's poll(2) misbehave).
    pub fn bind_threaded<H: SessionApi>(handle: H, addr: &str) -> Result<TcpServer> {
        TcpServer::bind_threaded_with_limit(handle, addr, None)
    }

    /// [`TcpServer::bind_threaded`] with the `--max-conns` cap.
    pub fn bind_threaded_with_limit<H: SessionApi>(
        handle: H,
        addr: &str,
        max_conns: Option<usize>,
    ) -> Result<TcpServer> {
        TcpServer::accept_loop(addr, max_conns, move |stream, guard| {
            let handle = handle.clone();
            std::thread::spawn(move || {
                let _guard = guard;
                serve_connection(stream, handle);
            });
        })
    }

    /// The shared accept loop: admission control (slot reservation and
    /// shedding happen here, before any handler exists), then hand the
    /// connection to the backend.
    fn accept_loop(
        addr: &str,
        max_conns: Option<usize>,
        serve: impl Fn(TcpStream, ConnGuard) + Send + 'static,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);
        let active = Arc::new(AtomicUsize::new(0));
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // Reserve the slot here, on the accept thread: admission
                // is decided before the handler exists, so a connection
                // burst cannot overshoot the cap.
                let prev = active.fetch_add(1, Ordering::SeqCst);
                if max_conns.is_some_and(|cap| prev >= cap) {
                    active.fetch_sub(1, Ordering::SeqCst);
                    shed_connection(stream);
                    continue;
                }
                let guard = ConnGuard::adopt(Arc::clone(&active));
                serve(stream, guard);
            }
        });
        Ok(TcpServer { addr: local, stop, accept_thread: Some(accept_thread), evloop: None })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block on the accept loop (the `wu-uct serve` foreground mode).
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        if let Some(t) = self.accept_thread.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept() with a throwaway connection to ourselves.
            let _ = TcpStream::connect(self.addr);
            let _ = t.join();
        }
        // Dropping the last EventLoop reference stops and joins the
        // reactors (the accept thread's clone is gone after the join).
        self.evloop = None;
    }
}

/// A minimal HTTP observability endpoint (`wu-uct serve --stats-addr`)
/// with two routes: `/metrics` answers `200 text/plain; version=0.0.4`
/// with [`ServiceMetrics::prometheus_text`] rendered from a fresh
/// aggregate snapshot, and `/healthz` answers a small JSON body
/// (`{"ok":true,"role":...,"shards":...,"hosts":...,"sessions_open":...}`)
/// for load-balancer and liveness probes. Anything else is a `404` so a
/// misconfigured scrape path fails loudly instead of silently graphing
/// the wrong endpoint. One thread per request, no keep-alive: scrape
/// cadence is seconds, not microseconds, and the snapshot itself is
/// O(buckets), so the simplest correct server wins.
pub struct StatsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl StatsServer {
    /// Bind `addr` (port 0 for ephemeral) and serve scrapes of `handle`.
    pub fn bind<H: SessionApi>(handle: H, addr: &str) -> Result<StatsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding stats addr {addr}"))?;
        let local = listener.local_addr().context("reading bound stats address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let handle = handle.clone();
                std::thread::spawn(move || serve_scrape(stream, handle));
            }
        });
        Ok(StatsServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for StatsServer {
    fn drop(&mut self) {
        let Some(t) = self.accept_thread.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = t.join();
    }
}

/// One scrape: read the request line for its path, drain the rest of the
/// head (through the blank line), route, reply, close. Errors just drop
/// the connection — the scraper retries.
fn serve_scrape<H: SessionApi>(stream: TcpStream, handle: H) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut request_line = String::new();
    match reader.read_line(&mut request_line) {
        Ok(0) | Err(_) => return,
        Ok(_) => {}
    }
    // `GET /metrics HTTP/1.1` → `/metrics`; query strings are ignored
    // (Prometheus appends none, probes sometimes add cache-busters).
    let path = request_line
        .split_whitespace()
        .nth(1)
        .map(|p| p.split('?').next().unwrap_or(p))
        .unwrap_or("")
        .to_string();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return, // peer closed before the blank line
            Ok(_) if line.trim().is_empty() => break,
            Ok(_) => {}
        }
    }
    let (status, content_type, body) = match path.as_str() {
        "/metrics" | "" => match handle.metrics() {
            Ok(mut m) => {
                // Shard schedulers know nothing about sockets: fold this
                // process's connection counters in at the scrape edge,
                // mirroring the `metrics` op.
                let (active, shed, panics) = connection_stats();
                m.active_connections += active;
                m.connections_shed += shed;
                m.handler_panics += panics;
                ("200 OK", "text/plain; version=0.0.4", m.prometheus_text())
            }
            Err(e) => (
                "500 Internal Server Error",
                "text/plain; version=0.0.4",
                format!("metrics snapshot failed: {e:#}\n"),
            ),
        },
        "/healthz" => match handle.health() {
            Ok(h) => {
                use crate::service::json::{obj, Json};
                let doc = obj([
                    ("ok", Json::Bool(true)),
                    ("role", Json::Str(h.role.to_string())),
                    ("shards", Json::Num(h.shards as f64)),
                    ("hosts", Json::Num(h.hosts as f64)),
                    ("sessions_open", Json::Num(h.sessions_open as f64)),
                    ("uptime_s", Json::Num(h.uptime_s)),
                ]);
                ("200 OK", "application/json", format!("{}\n", doc.render()))
            }
            Err(e) => (
                "500 Internal Server Error",
                "application/json",
                format!("{{\"ok\":false,\"error\":{:?}}}\n", format!("{e:#}")),
            ),
        },
        other => (
            "404 Not Found",
            "text/plain",
            format!("no route {other}; try /metrics or /healthz\n"),
        ),
    };
    let head = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = writer.write_all(head.as_bytes());
    let _ = writer.write_all(body.as_bytes());
    let _ = writer.flush();
}

/// One threaded-backend connection: read a raw line, dispatch, write the
/// reply line. On EOF or I/O error, close every session the connection
/// still owns.
fn serve_connection<H: SessionApi>(stream: TcpStream, handle: H) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut owned: Vec<u64> = Vec::new();
    let mut line: Vec<u8> = Vec::new();
    loop {
        line.clear();
        match reader.read_until(b'\n', &mut line) {
            Ok(0) | Err(_) => break, // EOF or connection error
            Ok(_) => {}
        }
        while line.last().is_some_and(|&b| b == b'\n' || b == b'\r') {
            line.pop();
        }
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        let (reply, effect) = handle_bytes(&handle, &line);
        match effect {
            LineEffect::Opened(sid) => owned.push(sid),
            LineEffect::Closed(sid) => owned.retain(|&s| s != sid),
            LineEffect::None => {}
        }
        if writer.write_all(reply.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
        if writer.flush().is_err() {
            break;
        }
    }
    for sid in owned {
        let _ = handle.close(sid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::json::Json;
    use crate::service::scheduler::{SearchService, ServiceConfig};
    use crate::service::shard::{ShardedConfig, ShardedService};
    use std::io::{BufRead, BufReader, Write};
    use std::time::Duration;

    fn start() -> (SearchService, TcpServer) {
        let svc = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        (svc, server)
    }

    fn request(
        reader: &mut BufReader<TcpStream>,
        writer: &mut TcpStream,
        line: &str,
    ) -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim()).expect("valid json reply")
    }

    #[test]
    fn episode_over_tcp() {
        let (_svc, server) = start();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        let v = request(&mut reader, &mut writer, r#"{"op":"ping"}"#);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));

        let v = request(
            &mut reader,
            &mut writer,
            r#"{"op":"open","env":"garnet","seed":5,"sims":10,"rollout":6}"#,
        );
        let sid = v.get("session").unwrap().as_u64().unwrap();
        let v = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
        assert_eq!(v.get("sims").unwrap().as_u64(), Some(10));
        let action = v.get("action").unwrap().as_u64().unwrap();
        let v = request(
            &mut reader,
            &mut writer,
            &format!(r#"{{"op":"advance","session":{sid},"action":{action}}}"#),
        );
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let v = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
        assert_eq!(v.get("unobserved").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn episode_over_tcp_against_sharded_service() {
        let svc = ShardedService::start(ShardedConfig {
            shards: 2,
            shard: ServiceConfig {
                expansion_workers: 1,
                simulation_workers: 2,
                ..ServiceConfig::default()
            },
            ..ShardedConfig::default()
        });
        let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let v = request(
            &mut reader,
            &mut writer,
            r#"{"op":"open","env":"garnet","seed":2,"sims":8,"rollout":6}"#,
        );
        let sid = v.get("session").unwrap().as_u64().unwrap();
        let v = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
        assert_eq!(v.get("quiescent").unwrap().as_bool(), Some(true));
        let v = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let v = request(&mut reader, &mut writer, r#"{"op":"metrics"}"#);
        assert_eq!(v.get("shards").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn episode_over_the_threaded_baseline_backend() {
        let svc = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let server = TcpServer::bind_threaded(svc.handle(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let v = request(&mut reader, &mut writer, r#"{"op":"ping"}"#);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let v = request(
            &mut reader,
            &mut writer,
            r#"{"op":"open","env":"garnet","seed":3,"sims":8,"rollout":6}"#,
        );
        let sid = v.get("session").unwrap().as_u64().unwrap();
        let v = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn dropped_connection_closes_orphan_sessions() {
        let (svc, server) = start();
        {
            let stream = TcpStream::connect(server.local_addr()).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let v = request(&mut reader, &mut writer, r#"{"op":"open","env":"garnet"}"#);
            assert!(v.get("session").is_some());
            // Connection dropped here without a close op.
        }
        // The reaper runs on the dispatch pool; poll briefly.
        let h = svc.handle();
        let mut open = usize::MAX;
        for _ in 0..100 {
            open = h.metrics().unwrap().sessions_open;
            if open == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(open, 0, "orphaned session was not reaped");
        drop(server);
    }

    #[test]
    fn malformed_lines_keep_the_connection_alive() {
        let (_svc, server) = start();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let v = request(&mut reader, &mut writer, "garbage");
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        let v = request(&mut reader, &mut writer, r#"{"op":"ping"}"#);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn invalid_utf8_lines_get_error_replies_not_disconnects() {
        let (_svc, server) = start();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // Raw invalid UTF-8 followed by newline.
        writer.write_all(&[0xFF, 0xC0, b'{', b'\n']).unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).expect("error reply is valid json");
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("utf-8"));
        // Connection still serves.
        let v = request(&mut reader, &mut writer, r#"{"op":"ping"}"#);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn pipelined_requests_get_replies_in_order() {
        let (_svc, server) = start();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // Burst of alternating good/bad requests written before any
        // reply is read: replies must come back FIFO, good/bad/good/...
        let mut burst = Vec::new();
        for _ in 0..16 {
            burst.extend_from_slice(b"{\"op\":\"ping\"}\nnot json\n");
        }
        writer.write_all(&burst).unwrap();
        writer.flush().unwrap();
        for i in 0..32 {
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            let v = Json::parse(reply.trim()).expect("valid json reply");
            let want_ok = i % 2 == 0;
            assert_eq!(
                v.get("ok").unwrap().as_bool(),
                Some(want_ok),
                "reply {i} out of order: {reply}"
            );
        }
    }

    #[test]
    fn event_loop_sustains_256_concurrent_connections() {
        let (_svc, server) = start();
        let addr = server.local_addr();
        let mut conns = Vec::with_capacity(256);
        for i in 0..256 {
            let stream = TcpStream::connect(addr).unwrap_or_else(|e| {
                panic!("connect {i} failed: {e}");
            });
            let writer = stream.try_clone().unwrap();
            conns.push((BufReader::new(stream), writer));
        }
        // Every connection is live at once — round-trip each while all
        // 256 stay open, then spot-check a few again.
        for (i, (reader, writer)) in conns.iter_mut().enumerate() {
            let v = request(reader, writer, r#"{"op":"ping"}"#);
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "conn {i}");
        }
        let (active, _, _) = connection_stats();
        assert!(active >= 256, "gauge must see all held connections, got {active}");
        for (reader, writer) in conns.iter_mut().step_by(64) {
            let v = request(reader, writer, r#"{"op":"ping"}"#);
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        }
    }

    #[test]
    fn stats_server_answers_http_scrapes_with_prometheus_text() {
        let (svc, _server) = start();
        let h = svc.handle();
        let sid = {
            use crate::env::garnet::Garnet;
            use crate::mcts::common::SearchSpec;
            use crate::service::scheduler::SessionOptions;
            let spec = SearchSpec {
                max_simulations: 8,
                rollout_limit: 6,
                max_depth: 8,
                ..SearchSpec::default()
            };
            h.open(
                Box::new(Garnet::new(15, 3, 20, 0.0, 4)),
                spec,
                SessionOptions::default(),
            )
            .unwrap()
        };
        h.think(sid, 8).unwrap();
        let stats = StatsServer::bind(h.clone(), "127.0.0.1:0").unwrap();
        let mut s = TcpStream::connect(stats.local_addr()).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut body = String::new();
        std::io::Read::read_to_string(&mut BufReader::new(s), &mut body).unwrap();
        assert!(body.starts_with("HTTP/1.0 200 OK\r\n"), "got: {body}");
        assert!(body.contains("text/plain; version=0.0.4"));
        assert!(body.contains("wuuct_thinks_total 1"));
        assert!(body.contains("wuuct_think_latency_ms_bucket"));
        assert!(body.contains("wuuct_held_replies_hwm"));
        assert!(body.contains(r#"le="+Inf""#));
        assert!(body.contains("wuuct_active_connections"), "scrape carries the conn gauge");
        assert!(body.contains("wuuct_connections_shed_total"));
        assert!(body.contains("wuuct_handler_panics_total"));
        assert!(body.contains("wuuct_deadline_misses_total"));
        assert!(body.contains("wuuct_deadline_sims_bucket"));
        h.close(sid).unwrap();
        drop(stats); // must not hang
    }

    /// One-shot HTTP GET against a [`StatsServer`], returning the full
    /// raw response (status line, headers, body).
    fn http_get(stats: &StatsServer, path: &str) -> String {
        let mut s = TcpStream::connect(stats.local_addr()).unwrap();
        s.write_all(format!("GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut body = String::new();
        std::io::Read::read_to_string(&mut BufReader::new(s), &mut body).unwrap();
        body
    }

    #[test]
    fn stats_server_healthz_reports_role_json() {
        let (svc, _server) = start();
        let stats = StatsServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        let raw = http_get(&stats, "/healthz");
        assert!(raw.starts_with("HTTP/1.0 200 OK\r\n"), "got: {raw}");
        assert!(raw.contains("application/json"), "got: {raw}");
        let body = raw.split("\r\n\r\n").nth(1).expect("body after blank line");
        let v = Json::parse(body.trim()).expect("healthz body is valid json");
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("role").unwrap().as_str(), Some("service"));
        assert_eq!(v.get("sessions_open").unwrap().as_u64(), Some(0));
        assert!(v.get("uptime_s").is_some());
    }

    #[test]
    fn stats_server_unknown_path_is_404() {
        let (svc, _server) = start();
        let stats = StatsServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        let raw = http_get(&stats, "/nope");
        assert!(raw.starts_with("HTTP/1.0 404 Not Found\r\n"), "got: {raw}");
        assert!(raw.contains("/metrics"), "404 body should name known routes: {raw}");
        // Query strings are stripped before routing.
        let raw = http_get(&stats, "/metrics?x=1");
        assert!(raw.starts_with("HTTP/1.0 200 OK\r\n"), "got: {raw}");
    }

    #[test]
    fn connection_cap_sheds_with_a_typed_busy_line() {
        let svc = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let server = TcpServer::bind_with_limit(svc.handle(), "127.0.0.1:0", Some(1)).unwrap();
        let (_, shed_before, _) = connection_stats();

        // Occupy the only slot and prove it is being served.
        let first = TcpStream::connect(server.local_addr()).unwrap();
        let mut w1 = first.try_clone().unwrap();
        let mut r1 = BufReader::new(first);
        let v = request(&mut r1, &mut w1, r#"{"op":"ping"}"#);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));

        // The second connection is shed unprompted: one busy line, then
        // EOF — never a silently hung socket.
        let second = TcpStream::connect(server.local_addr()).unwrap();
        let mut r2 = BufReader::new(second);
        let mut line = String::new();
        r2.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).expect("shed reply is valid json");
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "line: {line}");
        assert_eq!(v.get("busy").unwrap().as_bool(), Some(true), "line: {line}");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("capacity"));
        line.clear();
        assert_eq!(r2.read_line(&mut line).unwrap(), 0, "shed connection closes");
        let (_, shed_after, _) = connection_stats();
        assert!(shed_after > shed_before, "shed connections are counted");

        // Dropping the occupant frees the slot; the release runs on the
        // dispatch pool, so poll until a fresh connection serves.
        drop(w1);
        drop(r1);
        let mut served = false;
        for _ in 0..200 {
            let s = TcpStream::connect(server.local_addr()).unwrap();
            let mut w = s.try_clone().unwrap();
            let mut r = BufReader::new(s);
            let _ = w.write_all(b"{\"op\":\"ping\"}\n");
            let _ = w.flush();
            let mut reply = String::new();
            if r.read_line(&mut reply).unwrap_or(0) > 0 {
                if let Ok(v) = Json::parse(reply.trim()) {
                    if v.get("ok").and_then(|b| b.as_bool()) == Some(true) {
                        served = true;
                        break;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(served, "slot never freed after the occupant dropped");
    }

    #[test]
    fn a_panicking_connection_thread_releases_its_slot_and_is_counted() {
        // Slot already reserved, as the accept loop would have done.
        let active = Arc::new(AtomicUsize::new(1));
        let (_, _, panics_before) = connection_stats();
        let slot = Arc::clone(&active);
        let t = std::thread::spawn(move || {
            let _guard = ConnGuard::adopt(slot);
            panic!("handler blew up");
        });
        assert!(t.join().is_err(), "the panic must propagate to join");
        assert_eq!(active.load(Ordering::SeqCst), 0, "slot released despite the panic");
        let (_, _, panics_after) = connection_stats();
        assert!(panics_after > panics_before, "handler panics are counted, never silent");
    }

    #[test]
    fn server_shutdown_is_clean() {
        let (_svc, server) = start();
        let addr = server.local_addr();
        drop(server); // must not hang
        // A fresh connection to the dead server must fail (eventually).
        std::thread::sleep(Duration::from_millis(20));
        let refused = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        // The listener socket is closed; connect should error. (Some
        // platforms may accept briefly while the backlog drains — accept
        // either outcome but never a served request.)
        if let Ok(mut s) = refused {
            s.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
            let _ = s.write_all(b"{\"op\":\"ping\"}\n");
            let mut buf = String::new();
            let mut r = BufReader::new(s);
            let n = r.read_line(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "dead server must not answer");
        }
    }
}
