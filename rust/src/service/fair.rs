//! Virtual-deadline fair scheduling, extracted as a pure component.
//!
//! This is classic stride scheduling: every session ("lane") carries a
//! virtual deadline; the lane with the earliest deadline issues the next
//! rollout, and each issued rollout pushes that lane's deadline back by
//! its stride (`1 / weight`). Equal-weight lanes therefore converge to
//! equal worker shares regardless of arrival order or budget size.
//!
//! The component is deliberately free of threads, pools and sessions so
//! that the *same* policy code runs in two places:
//!
//! * the live scheduler ([`crate::service::scheduler`]), one instance per
//!   shard thread;
//! * the deterministic testkit ([`crate::testkit::harness`]), where the
//!   fairness bound is property-tested tick by tick under scripted
//!   latencies (`rust/tests/properties.rs`).
//!
//! Ties on the deadline are broken by the *lowest lane id*, never by map
//! iteration order — that is what makes scheduler traces replayable from
//! a seed (the golden-trace requirement of the testkit).

use std::collections::HashMap;

/// Per-session quality-of-service class, honored by the fair queue via
/// class-weighted strides: a `Latency` lane's effective weight is its
/// configured weight times [`QosClass::weight_factor`], so its stride is
/// that much shorter and it preempts `Throughput` lanes at equal
/// configured weight — interactive (deadline-bounded) sessions keep
/// issuing while batch sessions absorb the slack. Carried in
/// [`SessionOptions`](crate::service::SessionOptions) and on the wire as
/// the `open` op's `class` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosClass {
    /// Interactive, deadline-sensitive traffic; preempts batch.
    Latency,
    /// Batch, best-effort traffic (the default).
    #[default]
    Throughput,
}

impl QosClass {
    /// Multiplier applied to the session weight when its lane is
    /// admitted ([`FairQueue::admit_class`]).
    pub fn weight_factor(self) -> f64 {
        match self {
            QosClass::Latency => 4.0,
            QosClass::Throughput => 1.0,
        }
    }

    /// Stable wire name.
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Latency => "latency",
            QosClass::Throughput => "throughput",
        }
    }

    /// Inverse of [`QosClass::name`].
    pub fn from_name(name: &str) -> Option<QosClass> {
        match name {
            "latency" => Some(QosClass::Latency),
            "throughput" => Some(QosClass::Throughput),
            _ => None,
        }
    }
}

/// Per-lane stride state.
#[derive(Debug, Clone, Copy)]
struct Lane {
    /// Virtual deadline; earliest issues next.
    deadline: f64,
    /// Deadline increment per issued rollout (`1 / weight`).
    stride: f64,
}

/// The fair queue: a set of lanes racing on virtual time.
#[derive(Debug, Clone, Default)]
pub struct FairQueue {
    virtual_time: f64,
    lanes: HashMap<u64, Lane>,
}

impl FairQueue {
    pub fn new() -> FairQueue {
        FairQueue::default()
    }

    /// Register a lane. Its first deadline is the current virtual time, so
    /// a newcomer competes fairly with incumbents immediately. Weights are
    /// clamped to a tiny positive floor (a zero weight would never run).
    pub fn admit(&mut self, id: u64, weight: f64) {
        let stride = 1.0 / weight.max(1e-6);
        self.lanes.insert(id, Lane { deadline: self.virtual_time, stride });
    }

    /// [`FairQueue::admit`] with the lane's QoS class folded into its
    /// effective weight (class-weighted stride).
    pub fn admit_class(&mut self, id: u64, weight: f64, class: QosClass) {
        self.admit(id, weight * class.weight_factor());
    }

    pub fn remove(&mut self, id: u64) {
        self.lanes.remove(&id);
    }

    /// Re-enter the race after idling: a lane must not hoard credit
    /// accrued while it had nothing to issue, so its deadline snaps
    /// forward to at least the current virtual time.
    pub fn rejoin(&mut self, id: u64) {
        let now = self.virtual_time;
        if let Some(lane) = self.lanes.get_mut(&id) {
            lane.deadline = lane.deadline.max(now);
        }
    }

    /// The eligible lane with the earliest deadline; deadline ties break
    /// toward the lowest id (deterministic regardless of iteration order
    /// of `eligible`). Unknown ids are ignored.
    pub fn earliest(&self, eligible: impl Iterator<Item = u64>) -> Option<u64> {
        let mut best: Option<(f64, u64)> = None;
        for id in eligible {
            let Some(lane) = self.lanes.get(&id) else { continue };
            let better = match best {
                None => true,
                Some((bd, bid)) => {
                    lane.deadline < bd || (lane.deadline == bd && id < bid)
                }
            };
            if better {
                best = Some((lane.deadline, id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Account one issued rollout to `id`: virtual time advances to the
    /// lane's deadline, which then recedes by its stride.
    pub fn charge(&mut self, id: u64) {
        if let Some(lane) = self.lanes.get_mut(&id) {
            self.virtual_time = lane.deadline;
            lane.deadline += lane.stride;
        }
    }

    pub fn virtual_time(&self) -> f64 {
        self.virtual_time
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Issue `n` rollouts among `ids` (all always eligible), returning the
    /// per-lane issue counts.
    fn run(q: &mut FairQueue, ids: &[u64], n: usize) -> HashMap<u64, usize> {
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for _ in 0..n {
            let id = q.earliest(ids.iter().copied()).expect("some lane");
            q.charge(id);
            *counts.entry(id).or_default() += 1;
        }
        counts
    }

    #[test]
    fn equal_weights_share_equally() {
        let mut q = FairQueue::new();
        for id in [1, 2, 3] {
            q.admit(id, 1.0);
        }
        let counts = run(&mut q, &[1, 2, 3], 30);
        for id in [1, 2, 3] {
            assert_eq!(counts[&id], 10, "lane {id}");
        }
    }

    #[test]
    fn double_weight_gets_double_share() {
        let mut q = FairQueue::new();
        q.admit(1, 2.0);
        q.admit(2, 1.0);
        let counts = run(&mut q, &[1, 2], 30);
        assert_eq!(counts[&1], 20);
        assert_eq!(counts[&2], 10);
    }

    #[test]
    fn latency_class_preempts_equal_weight_throughput() {
        // Same configured weight, different class: the latency lane's
        // stride is weight_factor() times shorter, so it takes that
        // multiple of the issue share.
        let mut q = FairQueue::new();
        q.admit_class(1, 1.0, QosClass::Latency);
        q.admit_class(2, 1.0, QosClass::Throughput);
        let counts = run(&mut q, &[1, 2], 50);
        assert_eq!(counts[&1], 40, "latency lane gets 4x: {counts:?}");
        assert_eq!(counts[&2], 10);
    }

    #[test]
    fn qos_class_names_roundtrip() {
        for class in [QosClass::Latency, QosClass::Throughput] {
            assert_eq!(QosClass::from_name(class.name()), Some(class));
        }
        assert_eq!(QosClass::from_name("bulk"), None);
        assert_eq!(QosClass::default(), QosClass::Throughput);
        assert_eq!(QosClass::Throughput.weight_factor(), 1.0);
        assert!(QosClass::Latency.weight_factor() > 1.0);
    }

    #[test]
    fn ties_break_to_lowest_id_deterministically() {
        // Admission order must not matter: both orders give byte-identical
        // schedules (the golden-trace precondition).
        let schedule = |order: &[u64]| {
            let mut q = FairQueue::new();
            for &id in order {
                q.admit(id, 1.0);
            }
            let mut seq = Vec::new();
            for _ in 0..12 {
                let id = q.earliest([1, 2, 3].into_iter()).unwrap();
                q.charge(id);
                seq.push(id);
            }
            seq
        };
        assert_eq!(schedule(&[1, 2, 3]), schedule(&[3, 1, 2]));
        assert_eq!(schedule(&[1, 2, 3])[0], 1, "first tie goes to lowest id");
    }

    #[test]
    fn latecomer_starts_at_current_virtual_time() {
        let mut q = FairQueue::new();
        q.admit(1, 1.0);
        for _ in 0..50 {
            let id = q.earliest([1].into_iter()).unwrap();
            q.charge(id);
        }
        // Lane 2 arrives late: it must not get 50 issues of back-credit.
        q.admit(2, 1.0);
        let counts = run(&mut q, &[1, 2], 20);
        assert!(counts[&2] <= 11, "latecomer got {} of 20", counts[&2]);
        assert!(counts[&1] >= 9);
    }

    #[test]
    fn rejoin_forfeits_idle_credit() {
        let mut q = FairQueue::new();
        q.admit(1, 1.0);
        q.admit(2, 1.0);
        // Lane 2 idles while lane 1 issues alone.
        for _ in 0..40 {
            let id = q.earliest([1].into_iter()).unwrap();
            q.charge(id);
        }
        q.rejoin(2);
        let counts = run(&mut q, &[1, 2], 20);
        assert!(
            counts[&2] <= 11,
            "rejoined lane must not binge on idle credit: {counts:?}"
        );
    }

    #[test]
    fn remove_and_unknown_ids_are_ignored() {
        let mut q = FairQueue::new();
        q.admit(1, 1.0);
        q.remove(1);
        assert!(q.earliest([1, 99].into_iter()).is_none());
        q.charge(42); // no-op
        assert_eq!(q.virtual_time(), 0.0);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }
}
