//! Session leases with epoch fencing — what lets N stateless routers
//! serve hot-hot.
//!
//! Every *side-effecting placement decision* (open, migrate, seal
//! resolution) must hold the session's lease. Two routers racing the
//! same session — the PR 4 handshake hazard — now resolve at the lease
//! table: one acquires, the other observes the typed [`LeaseLost`]
//! error and backs off. Leases carry **epochs**: a lease taken over
//! (after the holder's TTL lapsed — a partitioned or crashed router)
//! gets a strictly higher epoch, and every later step of the holder's
//! in-flight operation re-validates `(owner, epoch)` before its side
//! effect. A fenced router cannot complete a handshake it started
//! before losing the lease, no matter how delayed its messages are.
//!
//! The table itself is an in-process shared structure (`Arc<Mutex>`):
//! the live deployment's routers share one via the process that owns it,
//! and the chaos scheduler shares one between its scripted routers. A
//! multi-process deployment would back the same five operations
//! (acquire / validate / extend / release / TTL takeover) with an
//! external linearizable store; the fencing rules proven here transfer
//! unchanged (see DESIGN.md §11).
//!
//! Time is caller-supplied milliseconds — no internal clock — so lease
//! expiry is deterministic under the chaos scheduler's virtual time.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Typed fencing error: the caller does not (or no longer does) hold
/// the session's lease. Carried over the wire as the `lease_lost:true`
/// marker so a remote router rebuilds it typed, like [`super::scheduler::Busy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseLost {
    pub session: u64,
}

impl std::fmt::Display for LeaseLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "lease lost: session {} is leased to another router; back off and retry",
            self.session
        )
    }
}

impl std::error::Error for LeaseLost {}

/// A granted lease: present this at every subsequent fenced step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    pub session: u64,
    pub owner: u64,
    pub epoch: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    owner: u64,
    epoch: u64,
    expires_ms: u64,
}

struct State {
    leases: HashMap<u64, Entry>,
    /// Epoch source: bumped on every grant/takeover, never reused.
    next_epoch: u64,
    /// Takeovers of expired leases (fencing events; observable in tests).
    takeovers: u64,
}

/// The shared lease table. Cheap to clone (`Arc` inside); all methods
/// take `&self`.
#[derive(Clone)]
pub struct LeaseTable {
    inner: Arc<Mutex<State>>,
    ttl_ms: u64,
}

impl LeaseTable {
    /// `ttl_ms`: how long a lease lives without [`LeaseTable::extend`].
    /// A holder that goes quiet for longer (partitioned, crashed) can be
    /// taken over by another router.
    pub fn new(ttl_ms: u64) -> LeaseTable {
        LeaseTable {
            inner: Arc::new(Mutex::new(State {
                leases: HashMap::new(),
                next_epoch: 0,
                takeovers: 0,
            })),
            ttl_ms: ttl_ms.max(1),
        }
    }

    /// Acquire the session's lease for `owner`. Grants when the session
    /// is unleased, already leased by `owner` (re-acquire extends, same
    /// epoch), or the holder's lease expired (takeover: **new epoch**,
    /// fencing the old holder). A live lease held by another owner ⇒
    /// [`LeaseLost`].
    pub fn acquire(&self, session: u64, owner: u64, now_ms: u64) -> Result<Lease, LeaseLost> {
        let mut st = self.inner.lock().unwrap();
        let expires_ms = now_ms.saturating_add(self.ttl_ms);
        match st.leases.get_mut(&session) {
            Some(e) if e.owner == owner => {
                e.expires_ms = expires_ms;
                let epoch = e.epoch;
                Ok(Lease { session, owner, epoch })
            }
            Some(e) if e.expires_ms <= now_ms => {
                st.next_epoch += 1;
                st.takeovers += 1;
                let epoch = st.next_epoch;
                st.leases.insert(session, Entry { owner, epoch, expires_ms });
                Ok(Lease { session, owner, epoch })
            }
            Some(_) => Err(LeaseLost { session }),
            None => {
                st.next_epoch += 1;
                let epoch = st.next_epoch;
                st.leases.insert(session, Entry { owner, epoch, expires_ms });
                Ok(Lease { session, owner, epoch })
            }
        }
    }

    /// Fencing check before a side effect: the lease must still be held
    /// by this `(owner, epoch)`. A takeover in between (higher epoch,
    /// different owner — or even the same owner re-acquiring after
    /// expiry) fails the check.
    pub fn validate(&self, lease: Lease) -> Result<(), LeaseLost> {
        let st = self.inner.lock().unwrap();
        match st.leases.get(&lease.session) {
            Some(e) if e.owner == lease.owner && e.epoch == lease.epoch => Ok(()),
            _ => Err(LeaseLost { session: lease.session }),
        }
    }

    /// Refresh the TTL of a held lease (the long-operation keepalive).
    /// Fails like [`LeaseTable::validate`] if the lease was taken over.
    pub fn extend(&self, lease: Lease, now_ms: u64) -> Result<(), LeaseLost> {
        let mut st = self.inner.lock().unwrap();
        match st.leases.get_mut(&lease.session) {
            Some(e) if e.owner == lease.owner && e.epoch == lease.epoch => {
                e.expires_ms = now_ms.saturating_add(self.ttl_ms);
                Ok(())
            }
            _ => Err(LeaseLost { session: lease.session }),
        }
    }

    /// Release a held lease. A stale `(owner, epoch)` release is a
    /// no-op — it must not evict a newer holder's lease.
    pub fn release(&self, lease: Lease) {
        let mut st = self.inner.lock().unwrap();
        if let Some(e) = st.leases.get(&lease.session) {
            if e.owner == lease.owner && e.epoch == lease.epoch {
                st.leases.remove(&lease.session);
            }
        }
    }

    /// Leases currently recorded (live or expired-but-untaken).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().leases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expired-lease takeovers so far (each one fenced an old holder).
    pub fn takeovers(&self) -> u64 {
        self.inner.lock().unwrap().takeovers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_router_is_fenced_while_lease_is_live() {
        let t = LeaseTable::new(100);
        let a = t.acquire(7, 1, 0).unwrap();
        assert_eq!(t.acquire(7, 2, 10), Err(LeaseLost { session: 7 }));
        assert!(t.validate(a).is_ok());
        t.release(a);
        assert!(t.acquire(7, 2, 20).is_ok());
    }

    #[test]
    fn reacquire_by_owner_extends_without_new_epoch() {
        let t = LeaseTable::new(100);
        let a = t.acquire(7, 1, 0).unwrap();
        let b = t.acquire(7, 1, 90).unwrap();
        assert_eq!(a.epoch, b.epoch, "same holder, same epoch");
        // The extension moved the deadline: still fenced at t=150.
        assert_eq!(t.acquire(7, 2, 150), Err(LeaseLost { session: 7 }));
    }

    #[test]
    fn expired_lease_is_taken_over_and_old_holder_is_fenced() {
        let t = LeaseTable::new(100);
        let old = t.acquire(7, 1, 0).unwrap();
        // Router 1 goes quiet; router 2 takes over after the TTL.
        let new = t.acquire(7, 2, 101).unwrap();
        assert!(new.epoch > old.epoch);
        assert_eq!(t.takeovers(), 1);
        // Router 1's delayed continuation hits the fence.
        assert_eq!(t.validate(old), Err(LeaseLost { session: 7 }));
        assert_eq!(t.extend(old, 102), Err(LeaseLost { session: 7 }));
        // Its stale release must not evict router 2's lease.
        t.release(old);
        assert!(t.validate(new).is_ok());
    }

    #[test]
    fn distinct_sessions_lease_independently() {
        let t = LeaseTable::new(100);
        let a = t.acquire(1, 1, 0).unwrap();
        let b = t.acquire(2, 2, 0).unwrap();
        assert!(t.validate(a).is_ok());
        assert!(t.validate(b).is_ok());
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn takeover_by_same_owner_after_expiry_bumps_epoch() {
        // A router that lost its own lease to time (GC pause) must also
        // be fenced against its *earlier* self: re-acquiring yields a
        // fresh epoch and the old guard fails validation.
        let t = LeaseTable::new(100);
        let old = t.acquire(7, 1, 0).unwrap();
        let new = t.acquire(7, 1, 500).unwrap();
        // Re-acquire by the same owner keeps the epoch (ownership never
        // lapsed to anyone else) — the owner's old guard stays valid.
        assert_eq!(old.epoch, new.epoch);
        // But once *another* owner took over and released, a re-acquire
        // is a fresh grant at a higher epoch.
        let stolen = t.acquire(7, 2, 700).unwrap();
        assert!(stolen.epoch > new.epoch);
        assert_eq!(t.validate(old), Err(LeaseLost { session: 7 }));
    }
}
