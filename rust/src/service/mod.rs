//! Search-as-a-service: many concurrent WU-UCT sessions multiplexed over
//! shared expansion/simulation pools, sharded across scheduler threads.
//!
//! The paper's core trick — tracking unobserved samples `O` so the master
//! never waits on in-flight work (Eqs. 4–6) — means the master loop is
//! non-blocking by construction. This layer exploits that: the loop is
//! extracted into the tick-driven [`SearchDriver`] (one per session, one
//! private tree each), a scheduler thread interleaves every live
//! session's select/queue/absorb ticks, and N such shards run side by
//! side behind a consistent-hash router with cross-shard work stealing
//! and `Busy` backpressure. Unlike tree-parallel serving designs, no lock
//! ever guards a tree — the contention pitfalls catalogued by Liu et al.
//! (2020) are sidestepped rather than mitigated.
//!
//! Layers, bottom up:
//!
//! * [`driver`] — the resumable WU-UCT master state machine (it lives
//!   beside the algorithm in [`crate::mcts::wu_uct::driver`] so the
//!   dependency points service → mcts, never back; re-exported here);
//! * [`fair`] — virtual-deadline fair scheduling, extracted pure so the
//!   deterministic testkit ([`crate::testkit`]) replays the exact policy
//!   the live scheduler runs;
//! * [`scheduler`] — one shard: sessions, shared pools, fair scheduling,
//!   lifecycle ops (`open`/`think`/`advance`/`best`/`close`) with tree
//!   reuse across moves ([`crate::tree::Tree::advance_root`]), admission
//!   control and steal-queue participation;
//! * [`placement`] / [`shard`] — the consistent-hash ring and the
//!   [`ShardedService`] router (`wu-uct serve --shards N`);
//! * [`metrics`] — think-latency percentiles, throughput, occupancy,
//!   steal/shed counters, per-shard and aggregated;
//! * [`json`] / [`proto`] — the line-delimited JSON wire protocol;
//! * [`server`] — the TCP front-end behind `wu-uct serve`;
//! * [`crate::store`] — durability and migration underneath it all:
//!   per-shard write-ahead session logs with crash recovery (`wu-uct
//!   serve --data-dir`), checksummed session images, live migration and
//!   the automatic occupancy rebalancer.

pub mod fair;
pub mod json;
pub mod metrics;
pub mod placement;
pub mod proto;
pub mod scheduler;
pub mod server;
pub mod shard;

use anyhow::Result;

use crate::env::Env;
use crate::mcts::common::SearchSpec;

pub use crate::mcts::wu_uct::driver;
pub use crate::mcts::wu_uct::driver::{AdvanceOutcome, IssueOutcome, SearchDriver, TaskSink};
pub use fair::FairQueue;
pub use metrics::ServiceMetrics;
pub use placement::HashRing;
pub use scheduler::{
    AdvanceReply, Busy, CloseReply, SearchService, ServiceConfig, ServiceHandle, SessionOptions,
    ThinkReply,
};
pub use server::TcpServer;
pub use shard::{
    MigrateOutcome, RebalanceConfig, ShardedConfig, ShardedHandle, ShardedService,
};

/// The session-lifecycle surface shared by the single-shard
/// [`ServiceHandle`] and the sharded [`ShardedHandle`] router. The wire
/// dispatcher ([`proto::handle_line`]) and the TCP server are generic
/// over it, so every transport serves either deployment unchanged.
pub trait SessionApi: Clone + Send + 'static {
    fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64>;
    fn think(&self, session: u64, sims: u32) -> Result<ThinkReply>;
    fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply>;
    fn best_action(&self, session: u64) -> Result<usize>;
    fn close(&self, session: u64) -> Result<CloseReply>;
    fn metrics(&self) -> Result<ServiceMetrics>;

    /// Per-shard snapshots; a single snapshot for an unsharded service.
    fn shard_metrics(&self) -> Result<Vec<ServiceMetrics>> {
        self.metrics().map(|m| vec![m])
    }

    /// Live-migrate a session to another shard. Only meaningful for the
    /// sharded router; everything else reports the obvious error.
    fn migrate(&self, _session: u64, _to_shard: usize) -> Result<MigrateOutcome> {
        anyhow::bail!("migration requires a sharded deployment (serve with --shards > 1)")
    }
}

impl SessionApi for ServiceHandle {
    fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64> {
        ServiceHandle::open(self, env, spec, opts)
    }

    fn think(&self, session: u64, sims: u32) -> Result<ThinkReply> {
        ServiceHandle::think(self, session, sims)
    }

    fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply> {
        ServiceHandle::advance(self, session, action)
    }

    fn best_action(&self, session: u64) -> Result<usize> {
        ServiceHandle::best_action(self, session)
    }

    fn close(&self, session: u64) -> Result<CloseReply> {
        ServiceHandle::close(self, session)
    }

    fn metrics(&self) -> Result<ServiceMetrics> {
        ServiceHandle::metrics(self)
    }
}
