//! Search-as-a-service: many concurrent WU-UCT sessions multiplexed over
//! one shared expansion pool and one shared simulation pool.
//!
//! The paper's core trick — tracking unobserved samples `O` so the master
//! never waits on in-flight work (Eqs. 4–6) — means the master loop is
//! non-blocking by construction. This layer exploits that: the loop is
//! extracted into the tick-driven [`SearchDriver`] (one per session, one
//! private tree each), and a single scheduler thread interleaves every
//! live session's select/queue/absorb ticks, routing pool results back by
//! a global task id. Unlike tree-parallel serving designs, no lock ever
//! guards a tree — the contention pitfalls catalogued by Liu et al.
//! (2020) are sidestepped rather than mitigated.
//!
//! Layers, bottom up:
//!
//! * [`driver`] — the resumable WU-UCT master state machine (it lives
//!   beside the algorithm in [`crate::mcts::wu_uct::driver`] so the
//!   dependency points service → mcts, never back; re-exported here);
//! * [`scheduler`] — sessions, shared pools, virtual-deadline fair
//!   scheduling, lifecycle ops (`open`/`think`/`advance`/`best`/`close`)
//!   with tree reuse across moves ([`crate::tree::Tree::advance_root`]);
//! * [`metrics`] — think-latency percentiles, throughput, occupancy;
//! * [`json`] / [`proto`] — the line-delimited JSON wire protocol;
//! * [`server`] — the TCP front-end behind `wu-uct serve`.

pub mod json;
pub mod metrics;
pub mod proto;
pub mod scheduler;
pub mod server;

pub use crate::mcts::wu_uct::driver;
pub use crate::mcts::wu_uct::driver::{AdvanceOutcome, IssueOutcome, SearchDriver, TaskSink};
pub use metrics::ServiceMetrics;
pub use scheduler::{
    AdvanceReply, CloseReply, SearchService, ServiceConfig, ServiceHandle, SessionOptions,
    ThinkReply,
};
pub use server::TcpServer;
