//! Search-as-a-service: many concurrent WU-UCT sessions multiplexed over
//! shared expansion/simulation pools, sharded across scheduler threads.
//!
//! The paper's core trick — tracking unobserved samples `O` so the master
//! never waits on in-flight work (Eqs. 4–6) — means the master loop is
//! non-blocking by construction. This layer exploits that: the loop is
//! extracted into the tick-driven [`SearchDriver`] (one per session, one
//! private tree each), a scheduler thread interleaves every live
//! session's select/queue/absorb ticks, and N such shards run side by
//! side behind a consistent-hash router with cross-shard work stealing
//! and `Busy` backpressure. Unlike tree-parallel serving designs, no lock
//! ever guards a tree — the contention pitfalls catalogued by Liu et al.
//! (2020) are sidestepped rather than mitigated.
//!
//! Layers, bottom up:
//!
//! * [`driver`] — the resumable WU-UCT master state machine (it lives
//!   beside the algorithm in [`crate::mcts::wu_uct::driver`] so the
//!   dependency points service → mcts, never back; re-exported here);
//! * [`fair`] — virtual-deadline fair scheduling, extracted pure so the
//!   deterministic testkit ([`crate::testkit`]) replays the exact policy
//!   the live scheduler runs;
//! * [`scheduler`] — one shard: sessions, shared pools, fair scheduling,
//!   lifecycle ops (`open`/`think`/`advance`/`best`/`close`) with tree
//!   reuse across moves ([`crate::tree::Tree::advance_root`]), admission
//!   control and steal-queue participation;
//! * [`placement`] / [`shard`] — the consistent-hash ring and the
//!   [`ShardedService`] router (`wu-uct serve --shards N`);
//! * [`metrics`] — mergeable log-bucket latency histograms
//!   ([`crate::obs::Histogram`]), throughput, occupancy, steal/shed and
//!   held-reply counters, per-shard and aggregated exactly by bucket
//!   addition (plus a Prometheus text rendering);
//! * [`crate::obs`] — the per-shard event journal behind the wire
//!   `trace` op: every think's admit → select → expand/sim → backprop →
//!   reply-held → durable → reply-sent timeline, stitched across shards
//!   and hosts by shard-tagged task ids and propagated trace ids;
//! * [`json`] / [`proto`] — the line-delimited JSON wire protocol,
//!   including the cross-process host ops (`export` / `import` /
//!   `install` / `health`) carrying hex-framed session images;
//! * [`server`] — the TCP front-end behind `wu-uct serve` and
//!   `wu-uct shard-host`;
//! * [`client`] / [`router`] — the cross-process tier: pooled line
//!   clients to remote shard hosts and the stateless router
//!   (`wu-uct serve --hosts a:p,b:p`) that places sessions on hosts by
//!   consistent hash and re-runs the live-migration handshake over the
//!   wire;
//! * [`membership`] / [`lease`] — the control plane: a live host table
//!   (hosts `join`, heartbeat, `drain`; missed beats ⇒ suspect ⇒
//!   standby failover) replacing any static `--hosts` list, and the
//!   epoch-fenced session lease table that lets N stateless routers run
//!   hot-hot — every side-effecting placement decision is guarded, and
//!   the loser of a race observes a typed [`LeaseLost`] instead of a
//!   split brain;
//! * [`crate::store`] — the storage engine underneath it all, behind
//!   the single [`crate::store::SessionStore`] interface the scheduler
//!   speaks: per-shard group-commit write-ahead logs (replies held on
//!   commit tickets, one fsync per batch) with crash recovery (`wu-uct
//!   serve --data-dir`), checksummed session images with delta
//!   snapshots (`--snapshot-every` / `--full-every`), live migration
//!   and the automatic occupancy rebalancer.

pub mod client;
pub mod evloop;
pub mod fair;
pub mod frame;
pub mod json;
pub mod lease;
pub mod membership;
pub mod metrics;
pub mod placement;
pub mod proto;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod shard;

use anyhow::Result;

use crate::env::Env;
use crate::mcts::common::SearchSpec;

pub use crate::mcts::wu_uct::driver;
pub use crate::mcts::wu_uct::driver::{AdvanceOutcome, IssueOutcome, SearchDriver, TaskSink};
pub use client::{HostClient, HostUnreachable};
pub use fair::{FairQueue, QosClass};
pub use lease::{Lease, LeaseLost, LeaseTable};
pub use membership::{HostInfo, HostState, HostTable, JoinOutcome};
pub use metrics::ServiceMetrics;
pub use placement::HashRing;
pub use router::{Router, RouterConfig, RouterHandle};
pub use scheduler::{
    AdvanceReply, Busy, Clock, CloseReply, SearchService, ServiceConfig, ServiceHandle,
    SessionOptions, SessionStat, ThinkReply, ZeroThink,
};
pub use server::{StatsServer, TcpServer};
pub use shard::{
    MigrateOutcome, RebalanceConfig, ShardedConfig, ShardedHandle, ShardedService,
};

/// Reply to the wire `health` op: who this process is and what it holds.
#[derive(Debug, Clone)]
pub struct HealthReply {
    /// `"service"` (unsharded), `"host"` (shard host) or `"router"`.
    pub role: &'static str,
    /// Scheduler shards in this process (0 for a router).
    pub shards: usize,
    /// Remote shard hosts behind this process (0 unless routing).
    pub hosts: usize,
    pub sessions_open: usize,
    pub uptime_s: f64,
    /// Open sessions with progress counters, ascending by id. The router
    /// tier reads these at start to re-learn its id floor, rebuild
    /// placement overrides and dedup sessions a crash mid-migration left
    /// on two hosts. Empty for a router (its hosts own the sessions).
    pub sessions: Vec<SessionStat>,
    /// Per-host reachability, probed live (router only).
    pub host_status: Vec<HostStatus>,
}

/// One remote host's probe result inside a router's [`HealthReply`].
#[derive(Debug, Clone)]
pub struct HostStatus {
    pub addr: String,
    pub reachable: bool,
    pub sessions_open: usize,
}

/// One remote host's metrics rollup, as listed by
/// [`SessionApi::host_metrics`] and rendered into the `metrics` reply's
/// `per_host` array.
#[derive(Debug, Clone)]
pub struct HostReport {
    pub addr: String,
    pub reachable: bool,
    /// Aggregated over the host's shards; default-zero when unreachable.
    pub metrics: ServiceMetrics,
}

impl HostReport {
    /// Fold per-host reports (one fleet sweep) into the router-level
    /// aggregate: reachable hosts' metrics sum, `hosts` counts the whole
    /// fleet, and the router's cumulative unreachable counter rides
    /// along. The single aggregation path shared by
    /// [`RouterHandle::metrics`] and the wire `metrics` op.
    pub fn aggregate(reports: &[HostReport], host_unreachable: u64) -> ServiceMetrics {
        let reachable: Vec<ServiceMetrics> = reports
            .iter()
            .filter(|r| r.reachable)
            .map(|r| r.metrics.clone())
            .collect();
        let mut total = ServiceMetrics::aggregate(&reachable);
        total.hosts = reports.len();
        total.host_unreachable = host_unreachable;
        total
    }
}

/// Reply to the wire `join` op: the router's verdict on a host
/// announcing itself (or re-announcing after a restart / network blip).
#[derive(Debug, Clone, Copy)]
pub struct JoinReply {
    pub outcome: JoinOutcome,
    /// The host's membership epoch after the join — monotone across
    /// every transition, so a stale pre-partition host can always be
    /// told apart from its own successor.
    pub epoch: u64,
}

/// One shard's standby-replication progress, as reported by the wire
/// `repl_status` op. The primary's resume handshake reads this to ship
/// only the suffix the standby is missing.
#[derive(Debug, Clone, Copy)]
pub struct ReplShardStatus {
    pub shard: usize,
    /// Stream incarnation the standby is following (0 = none yet).
    pub start: u64,
    /// Highest contiguous replication sequence applied.
    pub acked: u64,
}

/// Reply to the wire `promote` op: what a standby recovered when it was
/// told its primary is gone.
#[derive(Debug, Clone, Copy)]
pub struct PromoteReply {
    /// Sessions rebuilt from the replicated streams.
    pub sessions: usize,
    /// Logged `advance` steps replayed while rebuilding them.
    pub steps: u64,
}

/// The session-lifecycle surface shared by the single-shard
/// [`ServiceHandle`], the sharded [`ShardedHandle`] (a shard host) and
/// the cross-process [`RouterHandle`]. The wire dispatcher
/// ([`proto::handle_line`]) and the TCP server are generic over it, so
/// every transport serves any deployment unchanged.
pub trait SessionApi: Clone + Send + 'static {
    fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64>;
    fn think(&self, session: u64, sims: u32) -> Result<ThinkReply>;
    fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply>;
    fn best_action(&self, session: u64) -> Result<usize>;
    fn close(&self, session: u64) -> Result<CloseReply>;
    fn metrics(&self) -> Result<ServiceMetrics>;

    /// [`SessionApi::think`] carrying a caller-supplied trace id (0 =
    /// untraced). Session-hosting deployments stamp the id on every
    /// journal event the think produces; the router propagates it over
    /// the wire so a cross-host think stitches into one timeline. The
    /// default ignores the id — tracing degrades, thinks still serve.
    fn think_traced(&self, session: u64, sims: u32, trace: u64) -> Result<ThinkReply> {
        let _ = trace;
        self.think(session, sims)
    }

    /// Deadline-bounded anytime think (the wire `think` op's `think_ms`
    /// field): return the current best action when the clock expires,
    /// folding in-flight tasks back to quiescence first; `sims` still
    /// caps the budget. Session-hosting deployments override this; the
    /// default refuses rather than silently ignoring the deadline.
    fn think_deadline(
        &self,
        _session: u64,
        _sims: u32,
        _think_ms: u64,
        _trace: u64,
    ) -> Result<ThinkReply> {
        anyhow::bail!("deadline thinks require a deadline-aware deployment")
    }

    /// Read the event journal (the wire `trace` op): the newest `limit`
    /// events, oldest first, optionally filtered to one session's
    /// timeline. Sharded handles merge their shards' journals by
    /// timestamp; routers merge their hosts'. Default: no journal.
    fn trace(&self, session: Option<u64>, limit: usize) -> Result<Vec<crate::obs::Event>> {
        let _ = (session, limit);
        Ok(Vec::new())
    }

    /// Per-session search-health summary (the wire `inspect` op): tree
    /// size/depth, ΣO in flight, root visit entropy and the top-k root
    /// actions with their modified-UCT score terms — computed on the
    /// owning shard in O(top-k + root children), never an image export,
    /// and safe mid-think. Default: not a session-hosting deployment.
    fn inspect(&self, _session: u64, _topk: usize) -> Result<crate::obs::SearchSummary> {
        anyhow::bail!("inspect requires a session-hosting deployment")
    }

    /// Per-shard snapshots; a single snapshot for an unsharded service.
    fn shard_metrics(&self) -> Result<Vec<ServiceMetrics>> {
        self.metrics().map(|m| vec![m])
    }

    /// Per-remote-host rollups; empty unless this handle is a router.
    fn host_metrics(&self) -> Result<Vec<HostReport>> {
        Ok(Vec::new())
    }

    /// Cumulative remote calls lost to [`client::HostUnreachable`] — a
    /// cheap local gauge (no fleet probe); nonzero only on a router.
    fn host_unreachable_total(&self) -> u64 {
        0
    }

    /// Live-migrate a session to another shard (or, on a router, another
    /// host). Only meaningful for sharded/routed deployments; everything
    /// else reports the obvious error.
    fn migrate(&self, _session: u64, _to_shard: usize) -> Result<MigrateOutcome> {
        anyhow::bail!("migration requires a sharded deployment (serve with --shards > 1)")
    }

    /// Open under a caller-assigned session id (the router tier assigns
    /// ids before the owning host ever sees the open).
    fn open_with_id(
        &self,
        _id: u64,
        _env: Box<dyn Env>,
        _spec: SearchSpec,
        _opts: SessionOptions,
    ) -> Result<u64> {
        anyhow::bail!("explicit session ids require a session-hosting deployment")
    }

    /// Cross-process migration, source half: serialize the idle session
    /// to its checksummed image and **seal** it (every op on the local
    /// copy now reports the typed `Recovering` error) until
    /// [`SessionApi::resolve_seal`] declares where the image landed.
    fn export_image(&self, _session: u64) -> Result<Vec<u8>> {
        anyhow::bail!("session export requires a session-hosting deployment")
    }

    /// Cross-process migration, target half: decode, admit and install
    /// an exported image. On a durable deployment the WAL `Open` lands
    /// before this returns, so the source may safely forget its copy.
    fn import_image(&self, _bytes: Vec<u8>) -> Result<u64> {
        anyhow::bail!("session import requires a session-hosting deployment")
    }

    /// Resolve a seal left by [`SessionApi::export_image`]:
    /// `landed = true` means the image is durably installed elsewhere
    /// (forget the local copy, WAL `Close`); `landed = false` means the
    /// transfer was refused or failed (unseal; the local copy serves
    /// again). Unsealing an unsealed session is a no-op, so a router that
    /// cannot know whether its export request ever arrived can always
    /// abort safely.
    fn resolve_seal(&self, _session: u64, _landed: bool) -> Result<()> {
        anyhow::bail!("seal resolution requires a session-hosting deployment")
    }

    /// Membership, router side: a shard host announces itself (the wire
    /// `join` op), optionally declaring the standby replicating it. The
    /// router adds it to the live host table and starts placing sessions
    /// on it. Idempotent — a restarted host re-joins and is revived.
    fn join(&self, _addr: String, _standby: Option<String>) -> Result<JoinReply> {
        anyhow::bail!("membership requires the router tier (serve with --hosts or --join)")
    }

    /// Membership, router side: a host's periodic liveness beat.
    /// `Ok(false)` means the router does not know this host (it
    /// restarted, or the host was forgotten after a drain) — the host
    /// should re-`join`.
    fn heartbeat(&self, _addr: String) -> Result<bool> {
        anyhow::bail!("membership requires the router tier (serve with --hosts or --join)")
    }

    /// Membership, router side: stop placing sessions on `addr`, migrate
    /// its sessions to the remaining hosts, then forget it. Returns how
    /// many sessions moved.
    fn drain(&self, _addr: String) -> Result<usize> {
        anyhow::bail!("membership requires the router tier (serve with --hosts or --join)")
    }

    /// Standby replication, target side: apply one framed record batch
    /// (the wire `replicate` op) to this host's standby state for
    /// `shard`, returning the new contiguous ack. Torn, oversized or
    /// corrupt frames surface as typed [`crate::store::Error`]s.
    fn replicate_apply(&self, _shard: usize, _frame: Vec<u8>) -> Result<u64> {
        anyhow::bail!("replication requires a shard host (wu-uct shard-host)")
    }

    /// Standby replication, target side: per-shard stream progress, read
    /// by a reconnecting primary to resume from the suffix.
    fn replicate_status(&self) -> Result<Vec<ReplShardStatus>> {
        anyhow::bail!("replication requires a shard host (wu-uct shard-host)")
    }

    /// Standby replication, target side: the primary is gone — fold the
    /// replicated streams into live, durable sessions and start serving
    /// them. Idempotent: promoting twice replays nothing new.
    fn promote(&self) -> Result<PromoteReply> {
        anyhow::bail!("promotion requires a shard host (wu-uct shard-host)")
    }

    /// Liveness + identity probe (the wire `health` op).
    fn health(&self) -> Result<HealthReply> {
        let m = self.metrics()?;
        Ok(HealthReply {
            role: "service",
            shards: m.shards,
            hosts: 0,
            sessions_open: m.sessions_open,
            uptime_s: m.uptime.as_secs_f64(),
            sessions: Vec::new(),
            host_status: Vec::new(),
        })
    }
}

impl SessionApi for ServiceHandle {
    fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64> {
        ServiceHandle::open(self, env, spec, opts)
    }

    fn think(&self, session: u64, sims: u32) -> Result<ThinkReply> {
        ServiceHandle::think(self, session, sims)
    }

    fn think_traced(&self, session: u64, sims: u32, trace: u64) -> Result<ThinkReply> {
        ServiceHandle::think_traced(self, session, sims, trace)
    }

    fn think_deadline(
        &self,
        session: u64,
        sims: u32,
        think_ms: u64,
        trace: u64,
    ) -> Result<ThinkReply> {
        ServiceHandle::think_deadline(self, session, sims, think_ms, trace)
    }

    fn trace(&self, session: Option<u64>, limit: usize) -> Result<Vec<crate::obs::Event>> {
        ServiceHandle::trace(self, session, limit)
    }

    fn inspect(&self, session: u64, topk: usize) -> Result<crate::obs::SearchSummary> {
        ServiceHandle::inspect(self, session, topk)
    }

    fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply> {
        ServiceHandle::advance(self, session, action)
    }

    fn best_action(&self, session: u64) -> Result<usize> {
        ServiceHandle::best_action(self, session)
    }

    fn close(&self, session: u64) -> Result<CloseReply> {
        ServiceHandle::close(self, session)
    }

    fn metrics(&self) -> Result<ServiceMetrics> {
        ServiceHandle::metrics(self)
    }

    fn open_with_id(
        &self,
        id: u64,
        env: Box<dyn Env>,
        spec: SearchSpec,
        opts: SessionOptions,
    ) -> Result<u64> {
        ServiceHandle::open_with_id(self, id, env, spec, opts)
    }

    fn export_image(&self, session: u64) -> Result<Vec<u8>> {
        self.export_session(session)
    }

    fn import_image(&self, bytes: Vec<u8>) -> Result<u64> {
        self.import_session(bytes)
    }

    fn resolve_seal(&self, session: u64, landed: bool) -> Result<()> {
        if landed {
            self.forget_session(session)
        } else {
            self.unseal_session(session)
        }
    }

    fn health(&self) -> Result<HealthReply> {
        let m = ServiceHandle::metrics(self)?;
        let sessions = self.list_sessions()?;
        Ok(HealthReply {
            role: "service",
            shards: 1,
            hosts: 0,
            sessions_open: sessions.len(),
            uptime_s: m.uptime.as_secs_f64(),
            sessions,
            host_status: Vec::new(),
        })
    }
}
