//! Length-prefixed binary framing for the TCP wire — the fast lane next
//! to the line-JSON protocol ([`crate::service::proto`]).
//!
//! A connection's first byte picks its protocol for life: [`MAGIC`]
//! (0xB7) can never begin a JSON line (it is not valid UTF-8 as a leading
//! byte), so the server sniffs one byte and routes the whole connection
//! to either the line dispatcher or the frame dispatcher. JSON clients
//! are untouched; framed clients get the same ops with two upgrades —
//! requests/replies ride as raw payloads without per-line re-parsing
//! overhead, and session images stream in bounded chunks
//! ([`OP_BLOB_BEGIN`]/[`OP_BLOB_CHUNK`]/[`OP_BLOB_END`]) instead of
//! materializing as a 2× hex string under the
//! [`crate::service::proto::MAX_IMAGE_BYTES`] ceiling.
//!
//! Wire layout (all integers little-endian):
//!
//! ```text
//! +------+---------+----+-------+-----------+---------+------------+
//! | 0xB7 | version | op | flags | len (u32) | payload | fnv1a(u64) |
//! +------+---------+----+-------+-----------+---------+------------+
//!   1B      1B      1B     1B       4B         len B       8B
//! ```
//!
//! The trailing checksum is FNV-1a over header **and** payload
//! ([`crate::store::checksum`] — the same function the WAL uses), so a
//! flipped op byte is caught, not just payload damage.
//!
//! Decode discipline mirrors the JSON wire's: every malformed frame is a
//! **typed error** ([`FrameError`]) and never a dropped connection. Each
//! error names its own resync strategy, applied by [`FrameReader`]:
//!
//! * torn header / torn payload — not an error at all; wait for bytes;
//! * bad magic — skip forward to the next [`MAGIC`] byte (or the buffer
//!   end) and report how much was skipped;
//! * bad version / bad checksum — the length field still bounds the
//!   frame, so skip exactly that frame and resume at the next;
//! * oversized length — discard the advertised span *without buffering
//!   it* (a hostile 4 GiB length must not allocate 4 GiB), then resume.

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

/// First byte of every frame. 0xB7 is a UTF-8 continuation byte, so no
/// JSON line (or any valid UTF-8 text) can ever start with it — this is
/// what makes first-byte protocol sniffing sound.
pub const MAGIC: u8 = 0xB7;
/// Frame protocol version.
pub const VERSION: u8 = 1;

/// A request payload: one JSON request object, exactly the bytes a JSON
/// client would send as a line (without the newline).
pub const OP_REQ: u8 = 0x01;
/// A reply payload: one JSON reply object, as the line protocol renders.
pub const OP_REP: u8 = 0x02;
/// Start of a streamed blob; payload is a small JSON header describing
/// it (`{"op":"import",...}` upstream, `{"ok":true,...}` downstream).
pub const OP_BLOB_BEGIN: u8 = 0x10;
/// One bounded slice of blob bytes.
pub const OP_BLOB_CHUNK: u8 = 0x11;
/// End of a blob; payload is the total blob length as a u64 (a cheap
/// cross-check that no chunk went missing).
pub const OP_BLOB_END: u8 = 0x12;

/// Hard cap on one frame's payload. Anything larger streams as a blob.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 20;
/// Chunk size blobs are sliced into (¼ of the frame cap: big enough to
/// amortize the 16-byte overhead to noise, small enough to interleave).
pub const BLOB_CHUNK_BYTES: usize = 256 << 10;
/// Cap on one assembled blob (a whole streamed session image): 1 GiB —
/// 32× the old hex-line ceiling, still a bound a host can refuse early.
pub const MAX_BLOB_BYTES: u64 = 1 << 30;

/// Header bytes before the payload.
pub const HEADER_BYTES: usize = 8;
/// Trailer bytes after the payload (the FNV-1a checksum).
pub const TRAILER_BYTES: usize = 8;
/// Fixed per-frame overhead.
pub const FRAME_OVERHEAD: usize = HEADER_BYTES + TRAILER_BYTES;

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub op: u8,
    pub flags: u8,
    pub payload: Vec<u8>,
}

/// A malformed frame, as a typed error naming the damage. The reader has
/// already resynced when one of these is returned — the caller reports
/// it (an error reply, a counter) and keeps reading.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The buffer did not start with [`MAGIC`]; `skipped` junk bytes
    /// were discarded up to the next candidate frame start.
    BadMagic { skipped: usize },
    /// Unknown protocol version; the frame was skipped whole.
    BadVersion { got: u8 },
    /// Advertised payload length past [`MAX_FRAME_PAYLOAD`]; the span is
    /// being discarded without buffering.
    Oversized { len: u64 },
    /// Checksum mismatch; the frame was skipped whole.
    BadChecksum { want: u64, got: u64 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic { skipped } => {
                write!(f, "bad frame magic: skipped {skipped} junk bytes to resync")
            }
            FrameError::BadVersion { got } => {
                write!(f, "unsupported frame version {got} (this peer speaks {VERSION})")
            }
            FrameError::Oversized { len } => write!(
                f,
                "oversized frame: {len} byte payload exceeds the {MAX_FRAME_PAYLOAD} byte cap"
            ),
            FrameError::BadChecksum { want, got } => write!(
                f,
                "frame checksum mismatch: computed {want:#018x}, frame carries {got:#018x}"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// Encode one frame: header + payload + FNV-1a trailer.
pub fn encode_frame(op: u8, payload: &[u8]) -> Vec<u8> {
    assert!(
        payload.len() <= MAX_FRAME_PAYLOAD,
        "frame payload {} exceeds MAX_FRAME_PAYLOAD; stream it as a blob",
        payload.len()
    );
    let mut out = Vec::with_capacity(FRAME_OVERHEAD + payload.len());
    out.push(MAGIC);
    out.push(VERSION);
    out.push(op);
    out.push(0); // flags
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let sum = crate::store::checksum(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Incremental frame decoder over a byte stream: feed raw reads through
/// [`FrameReader::extend`], pull frames (or typed errors) out of
/// [`FrameReader::next`]. Holds at most one frame cap of buffered bytes;
/// oversized spans are discarded as they arrive, never stored.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes of an oversized frame still to swallow before parsing
    /// resumes.
    discard: u64,
    /// Total malformed frames survived on this stream.
    pub frames_skipped: u64,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Feed raw bytes from the socket.
    pub fn extend(&mut self, mut bytes: &[u8]) {
        if self.discard > 0 {
            let eat = (self.discard).min(bytes.len() as u64) as usize;
            self.discard -= eat as u64;
            bytes = &bytes[eat..];
        }
        if !bytes.is_empty() {
            self.buf.extend_from_slice(bytes);
        }
    }

    /// Bytes currently buffered (tests and backpressure accounting).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Decode the next frame. `Ok(None)` means "need more bytes"; an
    /// `Err` is one malformed frame, already resynced past — keep
    /// calling.
    pub fn next(&mut self) -> std::result::Result<Option<Frame>, FrameError> {
        if self.discard > 0 || self.buf.is_empty() {
            return Ok(None);
        }
        if self.buf[0] != MAGIC {
            // Junk at the head: scan forward to the next candidate magic
            // byte (or swallow everything) and report the gap.
            let skip = self.buf[1..]
                .iter()
                .position(|&b| b == MAGIC)
                .map(|p| p + 1)
                .unwrap_or(self.buf.len());
            self.buf.drain(..skip);
            self.frames_skipped += 1;
            return Err(FrameError::BadMagic { skipped: skip });
        }
        if self.buf.len() < HEADER_BYTES {
            return Ok(None); // torn header: wait
        }
        let len = u32::from_le_bytes([self.buf[4], self.buf[5], self.buf[6], self.buf[7]]) as u64;
        if len > MAX_FRAME_PAYLOAD as u64 {
            // Discard the advertised span without ever buffering it.
            let total = HEADER_BYTES as u64 + len + TRAILER_BYTES as u64;
            if (self.buf.len() as u64) >= total {
                self.buf.drain(..total as usize);
            } else {
                self.discard = total - self.buf.len() as u64;
                self.buf.clear();
            }
            self.frames_skipped += 1;
            return Err(FrameError::Oversized { len });
        }
        let total = HEADER_BYTES + len as usize + TRAILER_BYTES;
        if self.buf.len() < total {
            return Ok(None); // torn payload/trailer: wait
        }
        let body_end = HEADER_BYTES + len as usize;
        let version = self.buf[1];
        if version != VERSION {
            // The length field still bounds the frame: skip it cleanly.
            self.buf.drain(..total);
            self.frames_skipped += 1;
            return Err(FrameError::BadVersion { got: version });
        }
        let want = crate::store::checksum(&self.buf[..body_end]);
        let got = u64::from_le_bytes(
            self.buf[body_end..total].try_into().expect("trailer is 8 bytes"),
        );
        if want != got {
            self.buf.drain(..total);
            self.frames_skipped += 1;
            return Err(FrameError::BadChecksum { want, got });
        }
        let frame = Frame {
            op: self.buf[2],
            flags: self.buf[3],
            payload: self.buf[HEADER_BYTES..body_end].to_vec(),
        };
        self.buf.drain(..total);
        Ok(Some(frame))
    }
}

/// What a blob request resolves to on the client side: the streamed
/// bytes, or a plain reply line (typically a typed error).
#[derive(Debug)]
pub enum BlobOrReply {
    /// `header` is the [`OP_BLOB_BEGIN`] payload (a JSON line).
    Blob { header: String, bytes: Vec<u8> },
    Line(String),
}

/// A blocking framed connection — the client half of the binary
/// protocol, used by [`crate::service::client::HostClient`] for
/// image-carrying ops. Counts bytes both ways so callers can prove
/// wire-cost claims (the ≤ 1.05× image-bytes bound).
#[derive(Debug)]
pub struct FrameStream {
    stream: TcpStream,
    reader: FrameReader,
    bytes_out: u64,
    bytes_in: u64,
}

impl FrameStream {
    pub fn new(stream: TcpStream) -> FrameStream {
        FrameStream { stream, reader: FrameReader::new(), bytes_out: 0, bytes_in: 0 }
    }

    /// `(sent, received)` raw socket bytes over this stream's lifetime.
    pub fn wire_bytes(&self) -> (u64, u64) {
        (self.bytes_out, self.bytes_in)
    }

    /// Send one frame.
    pub fn send(&mut self, op: u8, payload: &[u8]) -> Result<()> {
        let frame = encode_frame(op, payload);
        self.stream.write_all(&frame).context("writing frame")?;
        self.bytes_out += frame.len() as u64;
        Ok(())
    }

    /// Send a blob: a BEGIN header, bounded chunks, and the length END.
    pub fn send_blob(&mut self, header: &str, bytes: &[u8]) -> Result<()> {
        self.send(OP_BLOB_BEGIN, header.as_bytes())?;
        for chunk in bytes.chunks(BLOB_CHUNK_BYTES) {
            self.send(OP_BLOB_CHUNK, chunk)?;
        }
        self.send(OP_BLOB_END, &(bytes.len() as u64).to_le_bytes())?;
        self.stream.flush().context("flushing blob")
    }

    /// Receive one frame, blocking. A server never sends malformed
    /// frames, so decode errors here are hard connection errors.
    pub fn recv(&mut self) -> Result<Frame> {
        loop {
            match self.reader.next() {
                Ok(Some(frame)) => return Ok(frame),
                Ok(None) => {}
                Err(e) => bail!("malformed frame from server: {e}"),
            }
            let mut chunk = [0u8; 64 << 10];
            let n = self.stream.read(&mut chunk).context("reading frame bytes")?;
            if n == 0 {
                bail!("connection closed mid-frame");
            }
            self.bytes_in += n as u64;
            self.reader.extend(&chunk[..n]);
        }
    }

    /// Receive a reply line ([`OP_REP`]).
    pub fn recv_reply(&mut self) -> Result<String> {
        let frame = self.recv()?;
        if frame.op != OP_REP {
            bail!("expected a reply frame, got op {:#04x}", frame.op);
        }
        String::from_utf8(frame.payload).context("reply frame is not UTF-8")
    }

    /// Receive either a streamed blob or a plain reply line (the typed
    /// error path of a blob op).
    pub fn recv_blob(&mut self) -> Result<BlobOrReply> {
        let first = self.recv()?;
        let header = match first.op {
            OP_REP => {
                return Ok(BlobOrReply::Line(
                    String::from_utf8(first.payload).context("reply frame is not UTF-8")?,
                ))
            }
            OP_BLOB_BEGIN => {
                String::from_utf8(first.payload).context("blob header is not UTF-8")?
            }
            other => bail!("expected a blob or reply frame, got op {other:#04x}"),
        };
        let mut bytes: Vec<u8> = Vec::new();
        loop {
            let frame = self.recv()?;
            match frame.op {
                OP_BLOB_CHUNK => {
                    if bytes.len() as u64 + frame.payload.len() as u64 > MAX_BLOB_BYTES {
                        bail!("blob exceeds the {MAX_BLOB_BYTES} byte cap");
                    }
                    bytes.extend_from_slice(&frame.payload);
                }
                OP_BLOB_END => {
                    let want = u64::from_le_bytes(
                        frame.payload.as_slice().try_into().context("blob END length field")?,
                    );
                    if want != bytes.len() as u64 {
                        bail!(
                            "blob length mismatch: END declares {want} bytes, received {}",
                            bytes.len()
                        );
                    }
                    return Ok(BlobOrReply::Blob { header, bytes });
                }
                other => bail!("unexpected op {other:#04x} inside a blob stream"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(reader: &mut FrameReader, bytes: &[u8]) -> Vec<std::result::Result<Frame, FrameError>> {
        reader.extend(bytes);
        let mut out = Vec::new();
        loop {
            match reader.next() {
                Ok(Some(f)) => out.push(Ok(f)),
                Ok(None) => break,
                Err(e) => out.push(Err(e)),
            }
        }
        out
    }

    #[test]
    fn round_trips_a_frame() {
        let mut r = FrameReader::new();
        let got = feed(&mut r, &encode_frame(OP_REQ, b"{\"op\":\"ping\"}"));
        assert_eq!(got.len(), 1);
        let f = got[0].as_ref().unwrap();
        assert_eq!((f.op, f.flags, f.payload.as_slice()), (OP_REQ, 0, &b"{\"op\":\"ping\"}"[..]));
    }

    #[test]
    fn reassembles_frames_split_at_every_byte_boundary() {
        let wire = encode_frame(OP_REP, b"hello");
        for cut in 1..wire.len() {
            let mut r = FrameReader::new();
            assert!(feed(&mut r, &wire[..cut]).is_empty(), "cut at {cut} yielded early");
            let got = feed(&mut r, &wire[cut..]);
            assert_eq!(got.len(), 1, "cut at {cut}");
            assert_eq!(got[0].as_ref().unwrap().payload, b"hello");
        }
    }

    #[test]
    fn junk_resyncs_to_the_next_magic_byte() {
        let mut wire = vec![0x00, 0x7f, 0x20];
        wire.extend_from_slice(&encode_frame(OP_REQ, b"after"));
        let mut r = FrameReader::new();
        let got = feed(&mut r, &wire);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], Err(FrameError::BadMagic { skipped: 3 }));
        assert_eq!(got[1].as_ref().unwrap().payload, b"after");
        assert_eq!(r.frames_skipped, 1);
    }

    #[test]
    fn checksum_flip_skips_one_frame_cleanly() {
        let mut bad = encode_frame(OP_REQ, b"corrupt me");
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        bad.extend_from_slice(&encode_frame(OP_REQ, b"survivor"));
        let mut r = FrameReader::new();
        let got = feed(&mut r, &bad);
        assert_eq!(got.len(), 2);
        assert!(matches!(got[0], Err(FrameError::BadChecksum { .. })));
        assert_eq!(got[1].as_ref().unwrap().payload, b"survivor");
    }

    #[test]
    fn oversized_length_is_discarded_without_buffering() {
        let mut wire = vec![MAGIC, VERSION, OP_REQ, 0];
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = FrameReader::new();
        let got = feed(&mut r, &wire);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], Err(FrameError::Oversized { len: u32::MAX as u64 }));
        // The advertised 4 GiB span streams through without being stored.
        let junk = vec![0xAA_u8; 1 << 16];
        for _ in 0..8 {
            assert!(feed(&mut r, &junk).is_empty());
            assert_eq!(r.buffered(), 0, "oversized span must not buffer");
        }
    }

    #[test]
    fn oversized_span_ends_and_parsing_resumes() {
        // A small "oversized" claim (cap + 1) so the test can actually
        // stream past it and find a healthy frame on the far side.
        let len = (MAX_FRAME_PAYLOAD + 1) as u32;
        let mut wire = vec![MAGIC, VERSION, OP_REQ, 0];
        wire.extend_from_slice(&len.to_le_bytes());
        wire.extend_from_slice(&vec![0u8; len as usize + TRAILER_BYTES]);
        wire.extend_from_slice(&encode_frame(OP_REQ, b"back"));
        let mut r = FrameReader::new();
        let got = feed(&mut r, &wire);
        assert_eq!(got.len(), 2);
        assert!(matches!(got[0], Err(FrameError::Oversized { .. })));
        assert_eq!(got[1].as_ref().unwrap().payload, b"back");
    }

    #[test]
    fn version_mismatch_skips_the_frame() {
        let mut wire = encode_frame(OP_REQ, b"future");
        wire[1] = VERSION + 9;
        // Recompute the checksum so only the version is at fault.
        let body_end = HEADER_BYTES + b"future".len();
        let sum = crate::store::checksum(&wire[..body_end]);
        wire[body_end..].copy_from_slice(&sum.to_le_bytes());
        wire.extend_from_slice(&encode_frame(OP_REQ, b"now"));
        let mut r = FrameReader::new();
        let got = feed(&mut r, &wire);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], Err(FrameError::BadVersion { got: VERSION + 9 }));
        assert_eq!(got[1].as_ref().unwrap().payload, b"now");
    }
}
