//! The multi-session scheduler: many live WU-UCT searches, one shared
//! expansion pool, one shared simulation pool — one instance per *shard*.
//!
//! One scheduler thread owns every session's [`SearchDriver`] plus the two
//! pools. Because the driver never blocks — it only `issue`s tasks and
//! `absorb`s results — the thread interleaves sessions freely: whenever a
//! worker slot frees up, the thinking session with the **earliest virtual
//! deadline** issues the next rollout ([`FairQueue`], the extracted
//! stride-scheduling component shared with the deterministic testkit).
//! Every session keeps a private tree; only *workers* are shared — the
//! tree-contention pitfalls catalogued by Liu et al. (2020) are sidestepped
//! rather than mitigated.
//!
//! Sharding ([`crate::service::shard`]) runs N of these threads side by
//! side. Each scheduler then carries a [`ShardWiring`]: its shard index,
//! senders to every peer inbox, an optional shared [`StealQueue`] for
//! cross-shard work stealing of overflowed simulation tasks, and an
//! optional per-shard session cap enforced at `open` with a typed
//! [`Busy`] error (the protocol's backpressure reply).
//!
//! Task results are routed back by a global task-id → session map; task
//! ids are tagged with the owning shard in their top 16 bits so a stolen
//! task's result can always find its way home. The paper's per-tree
//! invariant (`ΣO = 0` at quiescence, Eqs. 5–6) holds per session no
//! matter how thinks interleave or which shard ran the simulation — a
//! property-tested guarantee (`rust/tests/properties.rs`).

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::env::Env;
use crate::eval::{HeuristicPolicy, PolicyFactory};
use crate::mcts::common::SearchSpec;
use crate::mcts::wu_uct::driver::{SearchDriver, TaskSink};
use crate::mcts::wu_uct::workers::{Pool, Task, TaskResult};
use crate::obs::journal::DEFAULT_JOURNAL_CAP;
use crate::obs::{
    Event, EventKind, FlightConfig, FlightRecorder, Histogram, Journal, SearchSummary,
};
use crate::service::fair::{FairQueue, QosClass};
use crate::service::metrics::ServiceMetrics;
use crate::store::codec::{SessionImage, SessionMeta};
use crate::store::engine::{SessionStore, StoreCounters};
use crate::store::migrate::Recovering;
use crate::store::wal::Recovery;
use crate::store::Error as StoreError;

/// The scheduler's time source. Every timestamp the scheduler takes —
/// journal events, think latencies, deadline expiry — goes through this
/// seam instead of raw `Instant::now()`, so the deterministic testkit
/// can script a clock (deadline expiry becomes a plain store + poke,
/// provable with golden traces) while production pays one enum match.
#[derive(Clone)]
pub enum Clock {
    /// Wall time, measured from the instant the clock was created
    /// (scheduler start in production).
    Wall(Instant),
    /// Scripted microseconds: the owner of the cell advances time and
    /// pokes the scheduler inbox; the scheduler only ever reads it.
    Virtual(Arc<AtomicU64>),
}

impl Clock {
    /// A production clock starting now.
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    /// Microseconds since the clock's epoch.
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_micros() as u64,
            Clock::Virtual(cell) => cell.load(Ordering::Relaxed),
        }
    }

    /// Whether the scheduler may block with a timeout to detect deadline
    /// expiry (wall clocks only — a virtual clock never sleeps; its
    /// driver advances the cell and pokes the inbox).
    fn is_wall(&self) -> bool {
        matches!(self, Clock::Wall(_))
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::wall()
    }
}

/// Shared-pool sizing and defaults for one scheduler (one shard). Worker
/// counts are clamped to ≥ 1 at start (a zero-capacity pool could never
/// serve).
#[derive(Clone)]
pub struct ServiceConfig {
    pub expansion_workers: usize,
    pub simulation_workers: usize,
    /// Rollout policy every simulation worker uses.
    pub policy: PolicyFactory,
    /// Seed for the worker pools' policy streams.
    pub seed: u64,
    /// Cap on replies parked on commit tickets per shard (`None` =
    /// uncapped, the pre-cap behavior). At the cap the shard **sheds to
    /// a synchronous wait**: it forces the store durable
    /// ([`SessionStore::sync`]) and delivers instead of parking —
    /// bounded memory under a pathologically slow disk, degrading to
    /// backpressure instead of unbounded queueing.
    pub max_held: Option<usize>,
    /// Event-journal ring capacity per shard (`--journal-cap`; clamped
    /// to ≥ 1). A trace of a recent think is complete as long as the
    /// ring outlives the think; `journal_dropped` in the metrics says
    /// when it didn't.
    pub journal_cap: usize,
    /// Time source for journal timestamps, think latencies and deadline
    /// expiry (the [`Clock`] seam; wall time in production).
    pub clock: Clock,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            expansion_workers: 2,
            simulation_workers: 8,
            policy: HeuristicPolicy::factory(),
            seed: 0,
            max_held: None,
            journal_cap: DEFAULT_JOURNAL_CAP,
            clock: Clock::wall(),
        }
    }
}

/// Per-session knobs supplied at `open`.
#[derive(Debug, Clone)]
pub struct SessionOptions {
    /// Default simulations per think (0 ⇒ the spec's `max_simulations`).
    pub think_sims: u32,
    /// Fair-share weight; a weight-2 session gets twice the worker share
    /// of a weight-1 session under contention.
    pub weight: f64,
    /// Lifetime simulation budget; thinks clip to what remains and error
    /// once it is exhausted. `None` ⇒ unlimited.
    pub total_sim_budget: Option<u64>,
    /// Seed the environment was constructed with. Durable deployments
    /// (`--data-dir`) and live migration rebuild environments as
    /// `make_env(name, env_seed)` + snapshot restore, so envs may derive
    /// immutable structure from this seed (Garnet draws its whole MDP).
    /// The wire protocol sets it from the open request's `seed`.
    pub env_seed: u64,
    /// QoS class: `Latency` sessions get a class-weighted stride in the
    /// fair queue and preempt `Throughput` (default) sessions of equal
    /// configured weight — interactive deadline traffic stays responsive
    /// while batch traffic absorbs the slack.
    pub class: QosClass,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            think_sims: 0,
            weight: 1.0,
            total_sim_budget: None,
            env_seed: 0,
            class: QosClass::Throughput,
        }
    }
}

/// Typed admission-control rejection: the shard's session table is full.
/// Clients receive it as the wire protocol's explicit `busy` reply and
/// should retry (a re-open hashes to a fresh session id, which may land
/// on a different shard).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    /// Sessions currently open on the shard that rejected the open.
    pub open: usize,
    /// The shard's configured session cap.
    pub limit: usize,
}

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "busy: shard at capacity ({}/{} sessions open); retry later",
            self.open, self.limit
        )
    }
}

impl std::error::Error for Busy {}

/// Typed rejection of a think with no budget at all: `sims: 0` falls
/// back to the session's `think_sims` default, and when that is also 0
/// (and no `think_ms` deadline is set) admitting the think would hang
/// the caller on a search that can never issue. Rejected at admission
/// instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZeroThink {
    pub session: u64,
}

impl std::fmt::Display for ZeroThink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "think rejected: session {} has no simulation budget \
             (sims: 0 with a zero default and no deadline)",
            self.session
        )
    }
}

impl std::error::Error for ZeroThink {}

/// Reply to a completed think.
#[derive(Debug, Clone)]
pub struct ThinkReply {
    pub action: usize,
    pub value: f64,
    pub sims: u32,
    pub tree_size: usize,
    pub elapsed_ms: f64,
    /// `ΣO = 0` after the think (the paper's quiescence invariant).
    pub quiescent: bool,
    /// Lifetime simulations left, when a budget was set.
    pub remaining: Option<u64>,
    /// Deadline thinks (`think_ms`) only: `Some(true)` when the clock
    /// cut the search off mid-flight (in-flight tasks folded, current
    /// best returned), `Some(false)` when the full budget finished in
    /// time. `None` for plain thinks.
    pub cutoff: Option<bool>,
}

/// Reply to an `advance`.
#[derive(Debug, Clone)]
pub struct AdvanceReply {
    pub reward: f64,
    /// Episode finished with this step.
    pub done: bool,
    /// On-path subtree (with statistics) carried over as the new root.
    pub reused: bool,
    /// Nodes retained by the carry-over.
    pub retained: usize,
    /// Environment steps taken so far in this session.
    pub steps: u64,
}

/// Reply to a `close`.
#[derive(Debug, Clone)]
pub struct CloseReply {
    pub thinks: u64,
    pub sims: u64,
    pub steps: u64,
    /// Final `ΣO` of the session's tree (must be 0; tested).
    pub unobserved: u64,
}

pub(crate) enum Request {
    Open {
        env: Box<dyn Env>,
        spec: SearchSpec,
        opts: SessionOptions,
        /// Caller-assigned session id (the sharded router) or `None` for
        /// a scheduler-allocated one.
        id: Option<u64>,
        reply: Sender<Result<u64>>,
    },
    Think {
        session: u64,
        sims: u32,
        /// Wall-clock budget in milliseconds (0 = none): the think
        /// returns the current best action when the clock expires, even
        /// mid-search (`fold_in_flight` restores quiescence first).
        deadline_ms: u64,
        trace: u64,
        reply: Sender<Result<ThinkReply>>,
    },
    Advance { session: u64, action: usize, reply: Sender<Result<AdvanceReply>> },
    Best { session: u64, reply: Sender<Result<usize>> },
    Close { session: u64, reply: Sender<Result<CloseReply>> },
    /// Migration: serialize an idle session to its checksummed image.
    /// Read-only — the session keeps serving until [`Request::Forget`],
    /// so a crash mid-migration can duplicate but never lose it.
    Export { session: u64, reply: Sender<Result<Vec<u8>>> },
    /// Migration: install a session from an exported image (admission
    /// control applies; the WAL gets an `Open`).
    Import { bytes: Vec<u8>, reply: Sender<Result<u64>> },
    /// Migration/dedup: drop an idle session from this shard (the WAL
    /// gets a `Close`) — issued only after its image is durable
    /// elsewhere.
    Forget { session: u64, reply: Sender<Result<()>> },
    /// Migration abort: lift an [`Request::Export`] seal so the source
    /// copy serves again.
    Unseal { session: u64, reply: Sender<Result<()>> },
    /// Open sessions with their progress counters, ascending by id
    /// (recovery dedup and the rebalancer).
    ListSessions { reply: Sender<Vec<SessionStat>> },
    Metrics { reply: Sender<ServiceMetrics> },
    /// Read the shard's event journal: the newest `limit` events,
    /// optionally filtered to one session's timeline.
    Trace { session: Option<u64>, limit: usize, reply: Sender<Vec<Event>> },
    /// Compute one session's [`SearchSummary`] — valid mid-think (the
    /// scheduler thread owns the tree, so the snapshot is consistent)
    /// and O(top-k + root children), never an image export.
    Inspect { session: u64, topk: usize, reply: Sender<Result<SearchSummary>> },
    Shutdown,
}

pub(crate) enum SchedMsg {
    Request(Request),
    Done(TaskResult),
    /// A peer shard parked stealable work on the shared queue; wake up
    /// and run a dispatch pass.
    Poke,
    /// The store's committer made records durable through this sequence
    /// (or failed — the scheduler checks): release held replies.
    Durable(u64),
}

/// Cross-shard overflow queue of simulation tasks, tagged with the owning
/// shard so results can be routed home. Shared by every shard of one
/// [`crate::service::shard::ShardedService`].
#[derive(Default)]
pub(crate) struct StealQueue {
    queue: Mutex<VecDeque<(usize, Task)>>,
}

impl StealQueue {
    pub(crate) fn new() -> StealQueue {
        StealQueue::default()
    }

    pub(crate) fn push(&self, owner: usize, task: Task) {
        self.queue.lock().unwrap().push_back((owner, task));
    }

    pub(crate) fn pop(&self) -> Option<(usize, Task)> {
        self.queue.lock().unwrap().pop_front()
    }
}

/// Opens a shard's [`SessionStore`] and replays its recovery — built by
/// the sharded service (the live [`crate::store::SessionEngine`] over a
/// data dir) or by tests (the testkit's scripted store). The scheduler
/// itself never touches `Wal` or the codec's WAL records: everything
/// durable goes through the returned trait object.
pub(crate) type StoreOpener =
    Box<dyn FnOnce() -> Result<(Box<dyn SessionStore>, Recovery), StoreError> + Send>;

/// How one scheduler participates in a sharded deployment. The default
/// wiring (an unsharded [`SearchService`]) is shard 0 of 1, no stealing,
/// no session cap.
pub(crate) struct ShardWiring {
    pub index: usize,
    /// Inboxes of every shard (including this one), indexed by shard.
    pub peers: Vec<Sender<SchedMsg>>,
    /// Shared overflow queue; `None` disables stealing.
    pub steal: Option<std::sync::Arc<StealQueue>>,
    /// Admission control: max concurrently-open sessions on this shard.
    pub max_sessions: Option<usize>,
    /// Durability: this shard's session store. `None` keeps the shard
    /// memory-only (the pre-store behavior, bit for bit).
    pub store: Option<StoreOpener>,
    /// Tree-snapshot cadence in completed thinks per session (≥ 1; only
    /// meaningful with a store).
    pub snapshot_every: u32,
    /// Flight-recorder directory for this shard (`--flight-dir`); `None`
    /// keeps the journal memory-only. A recorder that fails to open or
    /// write disables itself — diagnostics never poison serving.
    pub flight: Option<PathBuf>,
}

struct ThinkJob {
    reply: Sender<Result<ThinkReply>>,
    /// [`Clock`] timestamp when the think was admitted.
    started_us: u64,
    /// Absolute [`Clock`] expiry for a `think_ms` think; `None` for
    /// plain budget-only thinks.
    deadline_us: Option<u64>,
    /// Caller-supplied trace id (0 = untraced); stamped on every journal
    /// event this think produces so a cross-host timeline stitches.
    trace: u64,
}

struct Session {
    driver: SearchDriver,
    thinking: Option<ThinkJob>,
    default_sims: u32,
    remaining: Option<u64>,
    thinks: u64,
    sims: u64,
    steps: u64,
    /// Fair-share weight (kept here so exports/snapshots can record it).
    weight: f64,
    /// Env construction seed (see [`SessionOptions::env_seed`]).
    env_seed: u64,
    /// Exported for migration and awaiting the forget/unseal decision:
    /// every mutating op is refused with the typed [`Recovering`] error,
    /// so nothing can change the session after its image left the shard
    /// (a racing write here would be silently lost on the target copy).
    sealed: bool,
    /// Best action reported by the previous completed think, for the
    /// flip counter below.
    last_best: Option<usize>,
    /// Times the recommended action changed across completed thinks — a
    /// cheap convergence signal surfaced by the `inspect` op.
    best_flips: u64,
}

/// A session rebuilt from the WAL, ready to install at scheduler start.
struct RecoveredParts {
    id: u64,
    driver: SearchDriver,
    meta: SessionMeta,
}

/// One open session's identity + progress, as listed by
/// [`Request::ListSessions`] and the wire `health` op. The progress
/// counters let recovery pick the most-advanced copy when a crash
/// mid-migration left a session on two shards — or, cross-process, when
/// a router boots against hosts that both hold a copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStat {
    pub id: u64,
    pub thinks: u64,
    pub steps: u64,
    /// Export-sealed awaiting seal resolution. A restarted router uses
    /// this to prefer the *unsealed* copy of a duplicated session (the
    /// sealed one was mid-hand-off) and to release a lone sealed copy
    /// whose resolution died with the previous router.
    pub sealed: bool,
}

/// Cloneable client handle; every op is a blocking round-trip to the
/// scheduler thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<SchedMsg>,
}

impl ServiceHandle {
    fn roundtrip<T>(&self, req: Request, rx: Receiver<T>) -> Result<T> {
        self.tx
            .send(SchedMsg::Request(req))
            .map_err(|_| anyhow!("search service stopped"))?;
        rx.recv().map_err(|_| anyhow!("search service stopped"))
    }

    /// Open a session rooted at `env`'s current state.
    pub fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Open { env, spec, opts, id: None, reply: tx }, rx)?
    }

    /// Open with a caller-assigned session id (the sharded router, which
    /// places ids by consistent hash before the shard ever sees them).
    pub(crate) fn open_with_id(
        &self,
        id: u64,
        env: Box<dyn Env>,
        spec: SearchSpec,
        opts: SessionOptions,
    ) -> Result<u64> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Open { env, spec, opts, id: Some(id), reply: tx }, rx)?
    }

    /// Run one think (`sims` = 0 ⇒ the session's default budget) and
    /// block until the search completes.
    pub fn think(&self, session: u64, sims: u32) -> Result<ThinkReply> {
        self.think_traced(session, sims, 0)
    }

    /// [`ServiceHandle::think`] with a caller-supplied trace id (0 =
    /// untraced) stamped on every journal event the think produces.
    pub fn think_traced(&self, session: u64, sims: u32, trace: u64) -> Result<ThinkReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Think { session, sims, deadline_ms: 0, trace, reply: tx }, rx)?
    }

    /// Deadline-bounded anytime think: returns the current best action
    /// when `think_ms` expires, folding in-flight tasks back to
    /// quiescence first. `sims` still caps the budget (0 ⇒ the session
    /// default; 0/0 runs until the clock alone).
    pub fn think_deadline(
        &self,
        session: u64,
        sims: u32,
        think_ms: u64,
        trace: u64,
    ) -> Result<ThinkReply> {
        let (tx, rx) = channel();
        self.roundtrip(
            Request::Think { session, sims, deadline_ms: think_ms, trace, reply: tx },
            rx,
        )?
    }

    /// Read this shard's event journal (newest `limit` events, oldest
    /// first), optionally filtered to one session's timeline.
    pub fn trace(&self, session: Option<u64>, limit: usize) -> Result<Vec<Event>> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Trace { session, limit, reply: tx }, rx)
    }

    /// One session's search-health summary (top-`topk` root actions).
    /// Works mid-think — that is the point: ΣO is only interesting while
    /// samples are in flight.
    pub fn inspect(&self, session: u64, topk: usize) -> Result<SearchSummary> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Inspect { session, topk, reply: tx }, rx)?
    }

    /// Execute `action` in the session's environment, reusing the on-path
    /// subtree as the new search root.
    pub fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Advance { session, action, reply: tx }, rx)?
    }

    /// Current recommended root action without searching further.
    pub fn best_action(&self, session: u64) -> Result<usize> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Best { session, reply: tx }, rx)?
    }

    pub fn close(&self, session: u64) -> Result<CloseReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Close { session, reply: tx }, rx)?
    }

    pub fn metrics(&self) -> Result<ServiceMetrics> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Metrics { reply: tx }, rx)
    }

    /// Migration: serialize an idle session's image (read-only; pair
    /// with [`ServiceHandle::forget_session`] once the image is durable
    /// on its new shard). Fails while a think is in flight.
    pub(crate) fn export_session(&self, session: u64) -> Result<Vec<u8>> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Export { session, reply: tx }, rx)?
    }

    /// Migration: install a session from an exported image.
    pub(crate) fn import_session(&self, bytes: Vec<u8>) -> Result<u64> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Import { bytes, reply: tx }, rx)?
    }

    /// Migration/dedup: drop an idle session (durably, via a WAL
    /// `Close`) after its image landed elsewhere.
    pub(crate) fn forget_session(&self, session: u64) -> Result<()> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Forget { session, reply: tx }, rx)?
    }

    /// Migration abort: lift the export seal (the target refused).
    pub(crate) fn unseal_session(&self, session: u64) -> Result<()> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Unseal { session, reply: tx }, rx)?
    }

    /// Open sessions (id + progress) on this shard, ascending by id.
    pub(crate) fn list_sessions(&self) -> Result<Vec<SessionStat>> {
        let (tx, rx) = channel();
        self.roundtrip(Request::ListSessions { reply: tx }, rx)
    }
}

/// The service: owns one scheduler thread (one shard); dropping shuts it
/// down. [`crate::service::shard::ShardedService`] runs several of these
/// against a shared steal queue.
pub struct SearchService {
    handle: ServiceHandle,
    thread: Option<JoinHandle<()>>,
}

impl SearchService {
    pub fn start(cfg: ServiceConfig) -> SearchService {
        let (tx, rx) = channel::<SchedMsg>();
        let wiring = ShardWiring {
            index: 0,
            peers: vec![tx.clone()],
            steal: None,
            max_sessions: None,
            store: None,
            snapshot_every: 1,
            flight: None,
        };
        SearchService::start_shard(cfg, wiring, tx, rx)
            .expect("memory-only shard start is infallible")
    }

    /// Start an unsharded service on a caller-supplied [`SessionStore`]
    /// — the injection seam the scripted store uses to prove group
    /// commit against the *live* scheduler (replies held on tickets,
    /// batches resolved at scripted sync points), and the embedding
    /// point for custom storage backends.
    pub fn start_with_store(
        cfg: ServiceConfig,
        snapshot_every: u32,
        opener: impl FnOnce() -> Result<(Box<dyn SessionStore>, Recovery), StoreError>
            + Send
            + 'static,
    ) -> Result<SearchService> {
        let (tx, rx) = channel::<SchedMsg>();
        let wiring = ShardWiring {
            index: 0,
            peers: vec![tx.clone()],
            steal: None,
            max_sessions: None,
            store: Some(Box::new(opener)),
            snapshot_every,
            flight: None,
        };
        SearchService::start_shard(cfg, wiring, tx, rx)
    }

    /// Start one shard on pre-wired channels (the sharded service creates
    /// every inbox first so peers can be cross-connected). With a store
    /// configured, the WAL is replayed here — in the caller's thread, so
    /// corruption surfaces as a typed error before the service exists —
    /// and the recovered sessions are installed before the scheduler
    /// accepts its first request.
    pub(crate) fn start_shard(
        cfg: ServiceConfig,
        mut wiring: ShardWiring,
        tx: Sender<SchedMsg>,
        rx: Receiver<SchedMsg>,
    ) -> Result<SearchService> {
        let durable_configured = wiring.store.is_some();
        let (store, recovered) = match wiring.store.take() {
            Some(opener) => {
                let (mut store, recovery) =
                    opener().context("opening this shard's session store")?;
                let mut sessions = Vec::with_capacity(recovery.sessions.len());
                for rs in recovery.sessions {
                    let id = rs.image.session;
                    let mut meta = rs.image.meta;
                    meta.steps += rs.advances.len() as u64;
                    let mut driver = rs
                        .image
                        .into_driver(crate::service::proto::make_env)
                        .with_context(|| format!("reviving session {id}"))?;
                    for action in rs.advances {
                        driver
                            .advance(action)
                            .with_context(|| format!("replaying advance on session {id}"))?;
                    }
                    sessions.push(RecoveredParts { id, driver, meta });
                }
                // Held replies release when the committer reports a batch
                // durable — through the scheduler's own inbox, so all
                // reply logic stays on the scheduler thread.
                let inbox = tx.clone();
                store.set_commit_notifier(Box::new(move |seq| {
                    let _ = inbox.send(SchedMsg::Durable(seq));
                }));
                (Some(store), sessions)
            }
            None => (None, Vec::new()),
        };
        let snapshot_every = wiring.snapshot_every.max(1);
        // Diagnostics must not block serving: a flight directory that
        // cannot open logs one line and the shard runs without it.
        let flight = wiring.flight.take().and_then(|dir| {
            match FlightRecorder::open(FlightConfig::new(&dir)) {
                Ok(rec) => Some(rec),
                Err(e) => {
                    eprintln!(
                        "shard {}: flight recorder disabled ({}): {e}",
                        wiring.index,
                        dir.display()
                    );
                    None
                }
            }
        });
        let journal_cap = cfg.journal_cap.max(1);
        // A zero-capacity pool would gate dispatch() shut forever and hang
        // every think() caller; clamp rather than hand out a dead service.
        let n_exp = cfg.expansion_workers.max(1);
        let n_sim = cfg.simulation_workers.max(1);
        // A zero cap would shed every reply; clamp to at least one slot.
        let max_held = cfg.max_held.map(|c| c.max(1));
        let clock = cfg.clock.clone();
        let mut expansion = Pool::new(n_exp, cfg.policy.clone(), cfg.seed ^ 0xe);
        let mut simulation = Pool::new(n_sim, cfg.policy.clone(), cfg.seed ^ 0x5);
        // Funnel both pools into the scheduler inbox so the thread blocks
        // on exactly one channel (std mpsc has no select).
        for pool in [&mut expansion, &mut simulation] {
            let results = pool.take_receiver();
            let inbox = tx.clone();
            std::thread::spawn(move || {
                while let Ok(r) = results.recv() {
                    if inbox.send(SchedMsg::Done(r)).is_err() {
                        break;
                    }
                }
            });
        }
        let thread = std::thread::spawn(move || {
            let mut sched = Scheduler {
                expansion,
                simulation,
                inbox: rx,
                shard: wiring,
                sessions: HashMap::new(),
                routes: HashMap::new(),
                stolen: HashMap::new(),
                overflow_ids: HashSet::new(),
                overflow_flag: false,
                fair: FairQueue::new(),
                next_session: 1,
                next_task: 1,
                pending_exp: 0,
                pending_sim: 0,
                opened: 0,
                closed: 0,
                rejected: 0,
                thinks: 0,
                sims: 0,
                sims_stolen: 0,
                sims_shed: 0,
                recovered: recovered.len() as u64,
                migrations_in: 0,
                migrations_out: 0,
                store,
                durable_configured,
                held: VecDeque::new(),
                held_hwm: 0,
                max_held,
                held_shed: 0,
                counters_cache: StoreCounters::default(),
                snapshot_every,
                think_hist: Histogram::new(),
                expand_hist: Histogram::new(),
                sim_hist: Histogram::new(),
                commit_hold_hist: Histogram::new(),
                deadline_sims_hist: Histogram::new(),
                deadline_hits: 0,
                deadline_misses: 0,
                journal: Journal::new(journal_cap),
                flight,
                issued_at: HashMap::new(),
                clock,
            };
            for parts in recovered {
                sched.install(parts.id, parts.driver, parts.meta);
            }
            sched.run()
        });
        Ok(SearchService { handle: ServiceHandle { tx }, thread: Some(thread) })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }
}

impl Drop for SearchService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(SchedMsg::Request(Request::Shutdown));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Scheduler state, owned by its shard thread.
struct Scheduler {
    expansion: Pool,
    simulation: Pool,
    inbox: Receiver<SchedMsg>,
    shard: ShardWiring,
    sessions: HashMap<u64, Session>,
    /// Global task id → session id (this shard's sessions only).
    routes: HashMap<u64, u64>,
    /// Tasks this shard is executing on behalf of peers: task id → owner
    /// shard, so the result can be forwarded home.
    stolen: HashMap<u64, usize>,
    /// Own tasks currently parked on the steal queue or running on a peer
    /// (they hold no local worker slot).
    overflow_ids: HashSet<u64>,
    /// Set when this dispatch round parked work on the steal queue; peers
    /// get poked afterwards.
    overflow_flag: bool,
    fair: FairQueue,
    next_session: u64,
    next_task: u64,
    pending_exp: usize,
    pending_sim: usize,
    opened: u64,
    closed: u64,
    /// Opens rejected by admission control ([`Busy`]).
    rejected: u64,
    thinks: u64,
    sims: u64,
    /// Simulation tasks executed here on behalf of peer shards.
    sims_stolen: u64,
    /// Own simulation tasks handed to the steal queue.
    sims_shed: u64,
    /// Sessions rebuilt from the WAL at boot.
    recovered: u64,
    /// Sessions imported from / exported to peer shards (migration).
    migrations_in: u64,
    migrations_out: u64,
    /// This shard's session store, when durable. The scheduler never
    /// touches the WAL or codec directly — only this interface.
    store: Option<Box<dyn SessionStore>>,
    /// Whether a store was configured at start (`store` may have been
    /// poisoned to `None` since; imports must still refuse rather than
    /// silently admit memory-only sessions on a durable shard).
    durable_configured: bool,
    /// Replies parked on their record's commit ticket, ascending by
    /// sequence; released when the committer reports the batch durable.
    held: VecDeque<Held>,
    /// Most replies ever parked at once (observability for the cap).
    held_hwm: usize,
    /// Cap on `held` ([`ServiceConfig::max_held`]); `None` = uncapped.
    max_held: Option<usize>,
    /// Replies that hit the cap and shed to a synchronous store flush.
    held_shed: u64,
    /// Last-known store counters (survives poisoning, so metrics keep
    /// reporting what was written before durability degraded).
    counters_cache: StoreCounters,
    /// Snapshot cadence in completed thinks per session.
    snapshot_every: u32,
    /// Mergeable latency distributions (O(1) record, O(buckets) scrape).
    think_hist: Histogram,
    expand_hist: Histogram,
    sim_hist: Histogram,
    commit_hold_hist: Histogram,
    /// Simulations completed when each deadline think finished (a count
    /// distribution; one sample per `think_ms` think, hit or miss).
    deadline_sims_hist: Histogram,
    /// Deadline thinks that finished their full budget before expiry.
    deadline_hits: u64,
    /// Deadline thinks the clock cut off mid-search.
    deadline_misses: u64,
    /// Ring journal of typed events; single-writer (this thread).
    journal: Journal,
    /// Crash-surviving spill of the journal: every event recorded above
    /// is teed here. `None` = not configured, failed to open, or went
    /// dead on a write error — serving is never affected.
    flight: Option<FlightRecorder>,
    /// Task id → journal timestamp at issue, for task-latency histograms
    /// (entries are removed when the result is absorbed).
    issued_at: HashMap<u64, u64>,
    /// Time source ([`Clock`] seam): wall in production, scripted in the
    /// testkit.
    clock: Clock,
}

/// A parked reply with the bookkeeping the journal and the
/// `commit_hold_ms` histogram need when it releases.
struct Held {
    /// Commit sequence the reply waits on.
    seq: u64,
    /// Session the op belonged to (0 for session-less ops).
    session: u64,
    /// Trace id propagated from the request (0 = untraced).
    trace: u64,
    /// Journal timestamp when the reply was parked.
    parked_at_us: u64,
    reply: HeldReply,
}

/// A reply whose op already executed in memory, parked until the record
/// that makes it durable commits. Group commit means many of these
/// resolve per fsync.
enum HeldReply {
    Open(Sender<Result<u64>>, u64),
    Think(Sender<Result<ThinkReply>>, ThinkReply),
    Advance(Sender<Result<AdvanceReply>>, AdvanceReply),
    Close(Sender<Result<CloseReply>>, CloseReply),
    Import(Sender<Result<u64>>, u64),
    Forget(Sender<Result<()>>),
}

impl HeldReply {
    fn deliver(self) {
        match self {
            HeldReply::Open(tx, v) => {
                let _ = tx.send(Ok(v));
            }
            HeldReply::Think(tx, v) => {
                let _ = tx.send(Ok(v));
            }
            HeldReply::Advance(tx, v) => {
                let _ = tx.send(Ok(v));
            }
            HeldReply::Close(tx, v) => {
                let _ = tx.send(Ok(v));
            }
            HeldReply::Import(tx, v) => {
                let _ = tx.send(Ok(v));
            }
            HeldReply::Forget(tx) => {
                let _ = tx.send(Ok(()));
            }
        }
    }
}

/// [`TaskSink`] over the shared pools for one session: allocates
/// shard-tagged global ids, records the route, tracks in-flight counts and
/// sheds overflow simulations to the cross-shard steal queue.
struct SharedSink<'a> {
    expansion: &'a Pool,
    simulation: &'a Pool,
    routes: &'a mut HashMap<u64, u64>,
    next_task: &'a mut u64,
    pending_exp: &'a mut usize,
    pending_sim: &'a mut usize,
    session: u64,
    /// `(shard index + 1) << 48`, OR-ed into every allocated task id.
    shard_tag: u64,
    shard_index: usize,
    sim_capacity: usize,
    /// Stolen-task slots currently busy on this shard's simulation pool.
    busy_stolen: usize,
    steal: Option<&'a StealQueue>,
    overflow_ids: &'a mut HashSet<u64>,
    overflow_flag: &'a mut bool,
    sims_shed: &'a mut u64,
    journal: &'a mut Journal,
    flight: &'a mut Option<FlightRecorder>,
    issued_at: &'a mut HashMap<u64, u64>,
    /// Journal timestamp for this drive pass.
    now_us: u64,
    /// Trace id of the session's in-flight think (0 = untraced).
    trace: u64,
}

impl SharedSink<'_> {
    fn next_id(&mut self) -> u64 {
        let id = self.shard_tag | *self.next_task;
        *self.next_task += 1;
        self.routes.insert(id, self.session);
        id
    }

    /// Simulation slots actually busy on the local pool right now.
    fn running_sims(&self) -> usize {
        self.pending_sim.saturating_sub(self.overflow_ids.len()) + self.busy_stolen
    }
}

impl SharedSink<'_> {
    fn journal_event(&mut self, task: u64, kind: EventKind, arg: u64) {
        let event = Event {
            at_us: self.now_us,
            session: self.session,
            task,
            trace: self.trace,
            kind,
            arg,
        };
        if let Some(f) = self.flight.as_mut() {
            f.record(&event);
        }
        self.journal.record(event);
    }
}

impl TaskSink for SharedSink<'_> {
    fn submit_expand(&mut self, env: Box<dyn Env>, action: usize, max_width: usize) -> u64 {
        let id = self.next_id();
        self.issued_at.insert(id, self.now_us);
        self.journal_event(id, EventKind::ExpandIssued, 0);
        self.expansion.submit(Task::Expand { task_id: id, env, action, max_width });
        *self.pending_exp += 1;
        id
    }

    fn submit_simulate(&mut self, env: Box<dyn Env>, gamma: f64, limit: u32) -> u64 {
        let id = self.next_id();
        self.issued_at.insert(id, self.now_us);
        self.journal_event(id, EventKind::SimIssued, 0);
        let task = Task::Simulate { task_id: id, env, gamma, limit };
        let saturated = self.running_sims() >= self.sim_capacity;
        *self.pending_sim += 1;
        match self.steal {
            // The local pool is saturated (this happens when expansion
            // results materialize their follow-up simulations): park the
            // task on the shared queue so an idle peer — or this shard,
            // once a slot frees — can pick it up.
            Some(queue) if saturated => {
                self.overflow_ids.insert(id);
                queue.push(self.shard_index, task);
                *self.overflow_flag = true;
                *self.sims_shed += 1;
                self.journal_event(id, EventKind::StealShed, self.shard_index as u64);
            }
            _ => self.simulation.submit(task),
        }
        id
    }
}

impl Scheduler {
    /// Journal timestamp: microseconds since this scheduler's clock
    /// epoch (scheduler start in production).
    fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Record one journal event at the current time (scheduler thread
    /// only — the journal is single-writer).
    fn journal_event(&mut self, session: u64, task: u64, trace: u64, kind: EventKind, arg: u64) {
        let at_us = self.now_us();
        let event = Event { at_us, session, task, trace, kind, arg };
        if let Some(f) = self.flight.as_mut() {
            f.record(&event);
        }
        self.journal.record(event);
    }

    /// Trace id of the session's in-flight think (0 when untraced/idle).
    fn trace_of(&self, sid: u64) -> u64 {
        self.sessions
            .get(&sid)
            .and_then(|s| s.thinking.as_ref())
            .map(|j| j.trace)
            .unwrap_or(0)
    }

    fn run(mut self) {
        loop {
            let msg = match self.recv_msg() {
                Some(m) => m,
                None => return, // every handle dropped
            };
            if !self.handle_msg(msg) {
                return;
            }
            // Drain whatever else queued up before refilling the pools.
            while let Ok(m) = self.inbox.try_recv() {
                if !self.handle_msg(m) {
                    return;
                }
            }
            self.expire_deadlines();
            self.dispatch();
            self.flush_held();
            self.maybe_checkpoint();
        }
    }

    /// Block for the next inbox message. With a wall clock and a
    /// deadline think pending, block at most until the nearest expiry so
    /// a deadline fires even when no worker result ever arrives to wake
    /// the thread; the timeout itself surfaces as a [`SchedMsg::Poke`].
    /// A virtual clock never sleeps — its test driver advances the cell
    /// and pokes the inbox explicitly.
    fn recv_msg(&mut self) -> Option<SchedMsg> {
        let wait = if self.clock.is_wall() {
            self.nearest_deadline_us().map(|due| {
                Duration::from_micros(due.saturating_sub(self.now_us()).max(1))
            })
        } else {
            None
        };
        match wait {
            Some(dur) => match self.inbox.recv_timeout(dur) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => Some(SchedMsg::Poke),
                Err(RecvTimeoutError::Disconnected) => None,
            },
            None => self.inbox.recv().ok(),
        }
    }

    /// Earliest absolute deadline across in-flight `think_ms` thinks.
    fn nearest_deadline_us(&self) -> Option<u64> {
        self.sessions
            .values()
            .filter_map(|s| s.thinking.as_ref().and_then(|j| j.deadline_us))
            .min()
    }

    /// Cut off every think whose deadline has passed: fold its in-flight
    /// tasks back to quiescence (the paper's Eq. 5 bookkeeping reversed
    /// — exactly what makes an anytime cutoff safe), truncate the budget
    /// to what completed, and finish with the current best action.
    fn expire_deadlines(&mut self) {
        let now = self.now_us();
        let due: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| {
                s.thinking
                    .as_ref()
                    .and_then(|j| j.deadline_us)
                    .is_some_and(|d| d <= now)
            })
            .map(|(&id, _)| id)
            .collect();
        for sid in due {
            self.cutoff_think(sid);
        }
    }

    /// The deadline half of [`Scheduler::finish_think`]: restore
    /// quiescence at the cutoff boundary, drop the folded tasks' routes
    /// (their results are still coming from the pools and must be
    /// orphaned, not absorbed into a truncated tree), and finish.
    fn cutoff_think(&mut self, sid: u64) {
        let Some(sess) = self.sessions.get_mut(&sid) else { return };
        let folded = sess.driver.fold_in_flight();
        sess.driver.truncate_budget();
        for id in &folded {
            self.routes.remove(id);
            self.issued_at.remove(id);
        }
        let trace = self.trace_of(sid);
        self.journal_event(sid, 0, trace, EventKind::DeadlineCut, folded.len() as u64);
        self.finish_think(sid, true);
    }

    /// Returns false on shutdown.
    fn handle_msg(&mut self, msg: SchedMsg) -> bool {
        match msg {
            SchedMsg::Request(req) => self.handle_request(req),
            SchedMsg::Done(result) => {
                self.handle_result(result);
                true
            }
            SchedMsg::Poke => true, // dispatch() after the drain pops steals
            SchedMsg::Durable(seq) => {
                // The committer resolved a batch through `seq`; this is
                // the scheduler-thread echo of the fsync, which keeps the
                // journal single-writer.
                self.journal_event(0, 0, 0, EventKind::WalFsync, seq);
                self.flush_held();
                true
            }
        }
    }

    fn handle_request(&mut self, req: Request) -> bool {
        match req {
            Request::Open { env, spec, opts, id, reply } => {
                match self.do_open(env, spec, opts, id) {
                    Ok((sid, seq)) => self.reply_or_hold(seq, sid, 0, HeldReply::Open(reply, sid)),
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Request::Think { session, sims, deadline_ms, trace, reply } => {
                match self.begin_think(session, sims, deadline_ms, trace, &reply) {
                    Ok(()) => {}
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Request::Advance { session, action, reply } => {
                match self.do_advance(session, action) {
                    Ok((out, seq)) => {
                        self.reply_or_hold(seq, session, 0, HeldReply::Advance(reply, out))
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Request::Best { session, reply } => {
                let _ = reply.send(
                    self.idle_session(session).map(|s| s.driver.best_action()),
                );
            }
            Request::Close { session, reply } => match self.do_close(session) {
                Ok((out, seq)) => self.reply_or_hold(seq, session, 0, HeldReply::Close(reply, out)),
                Err(e) => {
                    let _ = reply.send(Err(e));
                }
            },
            Request::Export { session, reply } => {
                let _ = reply.send(self.do_export(session));
            }
            Request::Import { bytes, reply } => match self.do_import(bytes) {
                Ok((sid, seq)) => self.reply_or_hold(seq, sid, 0, HeldReply::Import(reply, sid)),
                Err(e) => {
                    let _ = reply.send(Err(e));
                }
            },
            Request::Forget { session, reply } => match self.do_forget(session) {
                Ok(seq) => self.reply_or_hold(seq, session, 0, HeldReply::Forget(reply)),
                Err(e) => {
                    let _ = reply.send(Err(e));
                }
            },
            Request::Unseal { session, reply } => {
                let _ = reply.send(self.do_unseal(session));
            }
            Request::ListSessions { reply } => {
                let mut stats: Vec<SessionStat> = self
                    .sessions
                    .iter()
                    .map(|(&id, s)| SessionStat {
                        id,
                        thinks: s.thinks,
                        steps: s.steps,
                        sealed: s.sealed,
                    })
                    .collect();
                stats.sort_unstable_by_key(|s| s.id);
                let _ = reply.send(stats);
            }
            Request::Metrics { reply } => {
                let _ = reply.send(self.snapshot());
            }
            Request::Trace { session, limit, reply } => {
                let _ = reply.send(self.journal.query(session, limit));
            }
            Request::Inspect { session, topk, reply } => {
                let _ = reply.send(self.do_inspect(session, topk));
            }
            Request::Shutdown => return false,
        }
        true
    }

    /// Open a session; returns the id and, on a durable shard, the
    /// commit sequence the caller's reply must wait for.
    fn do_open(
        &mut self,
        env: Box<dyn Env>,
        spec: SearchSpec,
        opts: SessionOptions,
        id: Option<u64>,
    ) -> Result<(u64, Option<u64>)> {
        if let Some(limit) = self.shard.max_sessions {
            if self.sessions.len() >= limit {
                self.rejected += 1;
                return Err(anyhow::Error::new(Busy { open: self.sessions.len(), limit }));
            }
        }
        let id = match id {
            Some(id) => {
                if self.sessions.contains_key(&id) {
                    bail!("session id {id} already open on this shard");
                }
                id
            }
            None => {
                let id = self.next_session;
                self.next_session += 1;
                id
            }
        };
        let default_sims = if opts.think_sims > 0 {
            opts.think_sims
        } else {
            spec.max_simulations
        };
        let session = Session {
            driver: SearchDriver::new(spec, env.as_ref()),
            thinking: None,
            default_sims,
            remaining: opts.total_sim_budget,
            thinks: 0,
            sims: 0,
            steps: 0,
            weight: opts.weight,
            env_seed: opts.env_seed,
            sealed: false,
            last_best: None,
            best_flips: 0,
        };
        self.fair.admit_class(id, opts.weight, opts.class);
        self.sessions.insert(id, session);
        self.opened += 1;
        self.journal_event(id, 0, 0, EventKind::SessionOpen, self.shard.index as u64);
        let mut seq = None;
        if self.store.is_some() {
            match self.image_of(id) {
                Ok(image) => seq = self.log(|s| s.log_open(id, &image)),
                Err(e) => eprintln!("shard {}: open image failed: {e:#}", self.shard.index),
            }
            if let Some(seq) = seq {
                self.journal_event(id, 0, 0, EventKind::WalAppend, seq);
            }
        }
        Ok((id, seq))
    }

    /// Install a recovered or imported session under `id`.
    fn install(&mut self, id: u64, driver: SearchDriver, meta: SessionMeta) {
        self.fair.admit(id, meta.weight);
        self.next_session = self.next_session.max(id + 1);
        self.sessions.insert(
            id,
            Session {
                driver,
                thinking: None,
                default_sims: meta.default_sims,
                remaining: meta.remaining,
                thinks: meta.thinks,
                sims: meta.sims,
                steps: meta.steps,
                weight: meta.weight,
                env_seed: meta.env_seed,
                sealed: false,
                last_best: None,
                best_flips: 0,
            },
        );
    }

    /// Capture the session's current image (requires quiescence, which
    /// an idle session always has).
    fn image_of(&self, sid: u64) -> Result<SessionImage> {
        let sess = self
            .sessions
            .get(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        let meta = SessionMeta {
            env_seed: sess.env_seed,
            default_sims: sess.default_sims,
            weight: sess.weight,
            remaining: sess.remaining,
            thinks: sess.thinks,
            sims: sess.sims,
            steps: sess.steps,
        };
        Ok(SessionImage::capture(sid, &sess.driver, meta)?)
    }

    /// Run one logging verb against the store, if durable; returns the
    /// commit sequence to hold the op's reply on. A logging failure
    /// **poisons** the store: continuing to write after a lost record
    /// would leave a log whose replay hard-fails (an `Advance` with no
    /// `Open`, garbage mid-segment), permanently bricking the data dir.
    /// Instead the shard drops to memory-only serving and says so loudly
    /// — sessions stay alive, durability degrades, and the on-disk log
    /// remains replayable up to the failure point.
    fn log(
        &mut self,
        f: impl FnOnce(&mut dyn SessionStore) -> Result<crate::store::CommitTicket, StoreError>,
    ) -> Option<u64> {
        let store = self.store.as_deref_mut()?;
        match f(store) {
            Ok(ticket) => Some(ticket.seq()),
            Err(e) => {
                self.poison_store(&format!("store append failed ({e})"));
                None
            }
        }
    }

    /// Drop to memory-only serving and release everything parked on
    /// commit tickets. Most ops already executed in memory and only
    /// their durability is gone — exactly what degraded serving means —
    /// so their replies deliver Ok. Held **imports** are the exception:
    /// acking one tells the remote source to durably forget its copy,
    /// and without our Open on disk a later crash here would lose the
    /// session outright. Those are rolled back (uninstall) and refused,
    /// so the source unseals and keeps serving — the pre-group-commit
    /// refusal semantics, preserved.
    fn poison_store(&mut self, why: &str) {
        if let Some(store) = &self.store {
            self.counters_cache = store.counters();
        }
        if self.store.take().is_some() {
            eprintln!(
                "shard {}: {why}; durability DISABLED for this shard — serving \
                 memory-only from here on",
                self.shard.index
            );
        }
        for held in std::mem::take(&mut self.held) {
            self.journal_event(held.session, 0, held.trace, EventKind::ReplySent, 0);
            match held.reply {
                HeldReply::Import(tx, sid) => {
                    // The reply never left, so the router cannot have
                    // repointed anything at this copy yet; uninstalling
                    // is unobservable except as the refusal.
                    self.sessions.remove(&sid);
                    self.fair.remove(sid);
                    self.migrations_in = self.migrations_in.saturating_sub(1);
                    let _ = tx.send(Err(anyhow!(
                        "import refused: target could not log the session durably"
                    )));
                }
                other => other.deliver(),
            }
        }
    }

    /// Park a reply until its record's batch is durable — or deliver
    /// immediately when the op logged nothing (memory-only shard,
    /// poisoned store, or a think that skipped its snapshot cadence).
    ///
    /// With a [`ServiceConfig::max_held`] cap, a park that would exceed
    /// it **sheds to a synchronous wait** instead: force the store
    /// durable ([`SessionStore::sync`] — one flush admits the whole
    /// backlog), release everything, and deliver this reply directly.
    /// Held memory is bounded by the cap; a slow disk degrades to
    /// backpressure (callers block on fsync latency), never to
    /// unbounded queueing. The one exception is ack-gated replication:
    /// a local flush cannot conjure a standby ack, so a reply whose
    /// record the standby hasn't covered parks anyway — the cap bounds
    /// the *disk* backlog, and the journal still shows the shed.
    fn reply_or_hold(&mut self, seq: Option<u64>, session: u64, trace: u64, reply: HeldReply) {
        let mut durable = self.store.as_ref().map(|s| s.durable_seq()).unwrap_or(u64::MAX);
        if let (Some(seq), Some(cap)) = (seq, self.max_held) {
            if seq > durable && self.held.len() >= cap {
                self.held_shed += 1;
                if let Some(store) = self.store.as_deref_mut() {
                    store.sync();
                }
                self.flush_held(); // observes a sync failure and poisons
                durable = self.store.as_ref().map(|s| s.durable_seq()).unwrap_or(u64::MAX);
            }
        }
        match seq {
            Some(seq) if seq > durable => {
                let parked_at_us = self.now_us();
                self.journal_event(session, 0, trace, EventKind::ReplyHeld, seq);
                self.held.push_back(Held { seq, session, trace, parked_at_us, reply });
                self.held_hwm = self.held_hwm.max(self.held.len());
            }
            _ => {
                self.journal_event(session, 0, trace, EventKind::ReplySent, 0);
                reply.deliver();
            }
        }
    }

    /// Release held replies the committer has made durable; observe a
    /// commit failure and poison (which releases everything).
    fn flush_held(&mut self) {
        if self.store.is_none() {
            // Poisoned or memory-only: nothing can be (or stay) held.
            for held in std::mem::take(&mut self.held) {
                self.journal_event(held.session, 0, held.trace, EventKind::ReplySent, 0);
                held.reply.deliver();
            }
            return;
        }
        if let Some(e) = self.store.as_ref().and_then(|s| s.commit_error()) {
            self.poison_store(&format!("store commit failed ({e})"));
            return;
        }
        let durable = self.store.as_ref().map(|s| s.durable_seq()).unwrap_or(u64::MAX);
        while self.held.front().is_some_and(|h| h.seq <= durable) {
            let held = self.held.pop_front().expect("checked front");
            let now = self.now_us();
            let held_us = now.saturating_sub(held.parked_at_us);
            self.commit_hold_hist.record(held_us as f64 / 1e3);
            self.journal_event(held.session, 0, held.trace, EventKind::Durable, held.seq);
            self.journal_event(held.session, 0, held.trace, EventKind::ReplySent, held_us);
            held.reply.deliver();
        }
    }

    /// Compact the log once the live segment outgrows its budget. Idle
    /// sessions with records since their last full image re-image fresh;
    /// clean idle sessions (durable state already current) and mid-think
    /// sessions (cannot be imaged) are carried forward by the store from
    /// the old segments — no global idle instant is required, a
    /// perpetually busy shard still compacts, and a quiet fleet rewrites
    /// zero bytes.
    fn maybe_checkpoint(&mut self) {
        if !self.store.as_ref().is_some_and(|s| s.needs_checkpoint()) {
            return;
        }
        let mut ids: Vec<u64> = self.sessions.keys().copied().collect();
        ids.sort_unstable();
        let mut fresh = Vec::new();
        let mut carry = Vec::new();
        for id in ids {
            let idle = self.sessions[&id].thinking.is_none();
            let dirty = self.store.as_ref().is_some_and(|s| s.dirty(id));
            if idle && dirty {
                match self.image_of(id) {
                    Ok(image) => fresh.push((id, image)),
                    Err(e) => {
                        eprintln!(
                            "shard {}: checkpoint image failed: {e:#}",
                            self.shard.index
                        );
                        return;
                    }
                }
            } else {
                carry.push(id);
            }
        }
        if let Some(store) = self.store.as_deref_mut() {
            if let Err(e) = store.checkpoint(fresh, &carry) {
                // Same poisoning rationale as log(): a half-done
                // compaction must not keep accepting records.
                self.poison_store(&format!("checkpoint failed ({e})"));
            }
        }
    }

    /// Migration source half, phase 1: serialize an idle session and
    /// **seal** it. The session stays installed (and in this shard's
    /// WAL) until [`Scheduler::do_forget`] confirms the image is durable
    /// on its new shard — so a crash anywhere in between can duplicate
    /// the session but never lose it (recovery dedups, keeping the
    /// most-advanced copy) — while the seal refuses every op in the
    /// window, so no write can land on the source copy after its image
    /// left (it would be silently lost on the target otherwise).
    fn do_export(&mut self, sid: u64) -> Result<Vec<u8>> {
        self.idle_session(sid)?.sealed = true;
        // Exports always materialize a *full* image regardless of the
        // WAL's delta chains, so the wire format and the seal handshake
        // are untouched by delta encoding.
        let bytes = self
            .image_of(sid)
            .and_then(|img| img.encode().map_err(anyhow::Error::from));
        match &bytes {
            Ok(b) => {
                let len = b.len() as u64;
                self.journal_event(sid, 0, 0, EventKind::MigrateExport, len);
            }
            Err(_) => {
                if let Some(sess) = self.sessions.get_mut(&sid) {
                    sess.sealed = false;
                }
            }
        }
        bytes
    }

    /// Abort a migration: lift the seal so the source copy serves again
    /// (the target refused the import; nothing moved).
    fn do_unseal(&mut self, sid: u64) -> Result<()> {
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        sess.sealed = false;
        Ok(())
    }

    /// Migration source half, phase 2 (also recovery dedup): drop the
    /// session now that its image landed elsewhere. Sealed sessions are
    /// the expected case and cannot be mid-think (the seal blocks new
    /// thinks and was only granted at idleness).
    fn do_forget(&mut self, sid: u64) -> Result<Option<u64>> {
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        if sess.thinking.is_some() {
            bail!("session {sid} has a think in flight");
        }
        self.sessions.remove(&sid);
        self.fair.remove(sid);
        self.migrations_out += 1;
        let seq = self.log(|s| s.log_close(sid));
        self.journal_event(sid, 0, 0, EventKind::MigrateForget, seq.unwrap_or(0));
        if let Some(seq) = seq {
            self.journal_event(sid, 0, 0, EventKind::WalAppend, seq);
        }
        Ok(seq)
    }

    /// Migration target half: decode, admit and install.
    fn do_import(&mut self, bytes: Vec<u8>) -> Result<(u64, Option<u64>)> {
        if let Some(limit) = self.shard.max_sessions {
            if self.sessions.len() >= limit {
                self.rejected += 1;
                return Err(anyhow::Error::new(Busy { open: self.sessions.len(), limit }));
            }
        }
        let image = SessionImage::decode(&bytes)?;
        let image_len = bytes.len() as u64;
        let id = image.session;
        if self.sessions.contains_key(&id) {
            bail!("session id {id} already open on this shard");
        }
        let meta = image.meta;
        let driver = image.into_driver(crate::service::proto::make_env)?;
        // On a durable shard the Open must be on disk *before* the
        // import is acknowledged — which holding the reply on the commit
        // ticket guarantees: the source forgets (durably) only after it
        // sees our Ok, so a swallowed append failure here would let a
        // crash lose the session outright — the one thing the
        // export→import→forget ordering exists to prevent. A refused
        // import is safe: the source unseals and keeps serving.
        let mut seq = None;
        if self.durable_configured {
            let Some(store) = self.store.as_deref_mut() else {
                bail!("import refused: this shard's durability is disabled (store poisoned)");
            };
            match store.log_open_encoded(id, bytes, driver.tree()) {
                Ok(ticket) => seq = Some(ticket.seq()),
                Err(e) => {
                    self.poison_store(&format!("store append failed ({e})"));
                    bail!("import refused: target could not log the session durably");
                }
            }
        }
        self.install(id, driver, meta);
        self.migrations_in += 1;
        self.journal_event(id, 0, 0, EventKind::MigrateImport, image_len);
        if let Some(seq) = seq {
            self.journal_event(id, 0, 0, EventKind::WalAppend, seq);
        }
        Ok((id, seq))
    }

    /// Start a think; the reply is deferred until the budget drains (or
    /// the `deadline_ms` clock expires, whichever comes first).
    fn begin_think(
        &mut self,
        sid: u64,
        sims: u32,
        deadline_ms: u64,
        trace: u64,
        reply: &Sender<Result<ThinkReply>>,
    ) -> Result<()> {
        let now_us = self.clock.now_us();
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        if sess.sealed {
            return Err(anyhow::Error::new(Recovering { session: sid }));
        }
        if sess.thinking.is_some() {
            bail!("session {sid} already has a think in flight");
        }
        let mut budget = if sims > 0 { sims } else { sess.default_sims };
        if budget == 0 {
            if deadline_ms == 0 {
                // `sims: 0` with a zero session default and no deadline:
                // nothing bounds the think and nothing lets it issue —
                // admitting it would hang the caller forever.
                return Err(anyhow::Error::new(ZeroThink { session: sid }));
            }
            // Deadline-only think: the clock is the sole bound.
            budget = u32::MAX;
        }
        if let Some(rem) = sess.remaining {
            if rem == 0 {
                bail!("session {sid} has exhausted its simulation budget");
            }
            budget = budget.min(rem.min(u32::MAX as u64) as u32);
        }
        sess.driver.begin(budget);
        let deadline_us =
            (deadline_ms > 0).then(|| now_us.saturating_add(deadline_ms.saturating_mul(1000)));
        sess.thinking =
            Some(ThinkJob { reply: reply.clone(), started_us: now_us, deadline_us, trace });
        let done = sess.driver.done();
        self.journal_event(sid, 0, trace, EventKind::Admit, budget as u64);
        // A session that was idle re-enters the race at the current
        // virtual time (it must not hoard credit accrued while idle).
        self.fair.rejoin(sid);
        if done {
            self.finish_think(sid, false);
        }
        Ok(())
    }

    fn do_advance(&mut self, sid: u64, action: usize) -> Result<(AdvanceReply, Option<u64>)> {
        let sess = self.idle_session(sid)?;
        let out = sess.driver.advance(action)?;
        sess.steps += 1;
        let reply = AdvanceReply {
            reward: out.step.reward,
            done: out.step.done || sess.driver.env().is_terminal(),
            reused: out.reused,
            retained: out.retained,
            steps: sess.steps,
        };
        let seq = self.log(|s| s.log_advance(sid, action));
        if let Some(seq) = seq {
            self.journal_event(sid, 0, 0, EventKind::WalAppend, seq);
        }
        Ok((reply, seq))
    }

    fn do_close(&mut self, sid: u64) -> Result<(CloseReply, Option<u64>)> {
        self.idle_session(sid)?; // reject while a think is in flight
        let sess = self.sessions.remove(&sid).expect("checked above");
        self.fair.remove(sid);
        self.closed += 1;
        let seq = self.log(|s| s.log_close(sid));
        self.journal_event(sid, 0, 0, EventKind::SessionClose, 0);
        if let Some(seq) = seq {
            self.journal_event(sid, 0, 0, EventKind::WalAppend, seq);
        }
        Ok((
            CloseReply {
                thinks: sess.thinks,
                sims: sess.sims,
                steps: sess.steps,
                unobserved: sess.driver.tree().total_unobserved(),
            },
            seq,
        ))
    }

    /// Compute a session's search summary. Deliberately *not* gated on
    /// idleness or the migration seal: inspect is read-only, and ΣO is
    /// only nonzero mid-think — refusing then would blind the op to the
    /// very state it exists to observe.
    fn do_inspect(&self, sid: u64, topk: usize) -> Result<SearchSummary> {
        let sess = self
            .sessions
            .get(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        Ok(SearchSummary::compute(
            sid,
            sess.driver.tree(),
            sess.driver.spec().beta,
            sess.driver.unobserved(),
            sess.thinking.is_some(),
            sess.best_flips,
            topk,
        ))
    }

    /// The session, provided it exists, has no think in flight, and is
    /// not sealed for migration (sealed ops report the typed
    /// [`Recovering`] error — transient, retry on the session's new
    /// shard once routing repoints).
    fn idle_session(&mut self, sid: u64) -> Result<&mut Session> {
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        if sess.sealed {
            return Err(anyhow::Error::new(Recovering { session: sid }));
        }
        if sess.thinking.is_some() {
            bail!("session {sid} has a think in flight");
        }
        Ok(sess)
    }

    /// Run `f` with the session's driver and a sink wired to this shard's
    /// pools; `None` if the session vanished.
    fn drive<R>(
        &mut self,
        sid: u64,
        f: impl FnOnce(&mut Session, &mut SharedSink) -> R,
    ) -> Option<R> {
        let busy_stolen = self.stolen.len();
        let now_us = self.clock.now_us();
        let sess = self.sessions.get_mut(&sid)?;
        let trace = sess.thinking.as_ref().map(|j| j.trace).unwrap_or(0);
        let mut sink = SharedSink {
            expansion: &self.expansion,
            simulation: &self.simulation,
            routes: &mut self.routes,
            next_task: &mut self.next_task,
            pending_exp: &mut self.pending_exp,
            pending_sim: &mut self.pending_sim,
            session: sid,
            shard_tag: (self.shard.index as u64 + 1) << 48,
            shard_index: self.shard.index,
            sim_capacity: self.simulation.capacity(),
            busy_stolen,
            steal: self.shard.steal.as_deref(),
            overflow_ids: &mut self.overflow_ids,
            overflow_flag: &mut self.overflow_flag,
            sims_shed: &mut self.sims_shed,
            journal: &mut self.journal,
            flight: &mut self.flight,
            issued_at: &mut self.issued_at,
            now_us,
            trace,
        };
        Some(f(sess, &mut sink))
    }

    /// Route a pool result to its session and absorb it. Results of tasks
    /// stolen from a peer are forwarded to the owner's inbox instead.
    fn handle_result(&mut self, result: TaskResult) {
        let task_id = result.task_id();
        if let Some(owner) = self.stolen.remove(&task_id) {
            // Executed on behalf of a peer: hand the result home. A dead
            // peer (mid-shutdown) just drops it.
            if let Some(peer) = self.shard.peers.get(owner) {
                let _ = peer.send(SchedMsg::Done(result));
            }
            return;
        }
        let is_expand = match &result {
            TaskResult::Expanded(_) => {
                self.pending_exp = self.pending_exp.saturating_sub(1);
                true
            }
            TaskResult::Simulated(_) => {
                self.pending_sim = self.pending_sim.saturating_sub(1);
                false
            }
        };
        let was_overflow = self.overflow_ids.remove(&task_id);
        let issued_at = self.issued_at.remove(&task_id);
        let Some(sid) = self.routes.remove(&task_id) else {
            // Session vanished mid-flight (cannot happen: close requires
            // quiescence) — drop defensively rather than poison the loop.
            return;
        };
        // Task latency (issue → absorbed result): stolen tasks included,
        // the peer round trip is real latency the session experienced.
        let trace = self.trace_of(sid);
        let task_us = issued_at.map(|t0| self.now_us().saturating_sub(t0)).unwrap_or(0);
        if is_expand {
            self.expand_hist.record(task_us as f64 / 1e3);
            self.journal_event(sid, task_id, trace, EventKind::ExpandDone, task_us);
        } else {
            self.sim_hist.record(task_us as f64 / 1e3);
            if was_overflow {
                // The task came back from the steal queue (a peer ran it,
                // or this shard reclaimed it on a freed slot).
                self.journal_event(sid, task_id, trace, EventKind::StealClaim, task_us);
            }
            self.journal_event(sid, task_id, trace, EventKind::SimDone, task_us);
        }
        let done = self.drive(sid, |sess, sink| {
            sess.driver.absorb(result, sink);
            sess.thinking.is_some() && sess.driver.done()
        });
        self.journal_event(sid, task_id, trace, EventKind::Backprop, 0);
        if done == Some(true) {
            self.finish_think(sid, false);
        }
    }

    /// Simulation slots free on the local pool: capacity minus own tasks
    /// actually running here minus stolen tasks running here. Own tasks
    /// parked on the steal queue (or running on a peer) hold no slot.
    fn free_sim_slots(&self) -> usize {
        let running_own = self.pending_sim.saturating_sub(self.overflow_ids.len());
        self.simulation
            .capacity()
            .saturating_sub(running_own + self.stolen.len())
    }

    /// Pull parked simulation tasks — our own overflow or a peer's — onto
    /// free local slots.
    fn pop_steals(&mut self) {
        let Some(queue) = self.shard.steal.clone() else { return };
        while self.free_sim_slots() > 0 {
            let Some((owner, task)) = queue.pop() else { break };
            let task_id = match &task {
                Task::Simulate { task_id, .. } => *task_id,
                Task::Expand { task_id, .. } => *task_id,
                Task::Shutdown => continue, // never parked; skip defensively
            };
            if owner == self.shard.index {
                // Reclaimed our own overflow: it occupies a local slot
                // again and routes normally.
                self.overflow_ids.remove(&task_id);
            } else {
                self.stolen.insert(task_id, owner);
                self.sims_stolen += 1;
            }
            self.simulation.submit(task);
        }
    }

    /// Fill free worker slots: repeatedly let the thinking session with
    /// the earliest virtual deadline issue one rollout.
    fn dispatch(&mut self) {
        self.pop_steals();
        loop {
            // A rollout's kind is unknown until selection runs, so the
            // gate cannot be exact per pool. Requiring headroom in BOTH
            // pools (the dedicated master's gate) would let a saturated
            // 2-worker expansion pool stall simulation-bound rollouts for
            // every session and idle the whole simulation fleet. Instead:
            // always require a free simulation slot (every rollout ends
            // in a simulation), and let the expansion backlog run ahead
            // of its pool by at most the free simulation capacity —
            // bounded in-flight work without cross-pool head-of-line
            // blocking.
            let free_sim = self.free_sim_slots();
            if free_sim == 0 || self.pending_exp >= self.expansion.capacity() + free_sim {
                break;
            }
            let Some(sid) = self.fair.earliest(
                self.sessions
                    .iter()
                    .filter(|(_, s)| s.thinking.is_some() && s.driver.can_issue())
                    .map(|(&id, _)| id),
            ) else {
                break;
            };
            self.fair.charge(sid);
            let trace = self.trace_of(sid);
            self.journal_event(sid, 0, trace, EventKind::Select, 0);
            let done = self.drive(sid, |sess, sink| {
                sess.driver.issue(sink);
                // Terminal short-circuits can complete a think
                // synchronously.
                sess.driver.done()
            });
            if done == Some(true) {
                self.finish_think(sid, false);
            }
        }
        if std::mem::take(&mut self.overflow_flag) {
            // Parked work this round: wake idle peers so it gets stolen.
            for (i, peer) in self.shard.peers.iter().enumerate() {
                if i != self.shard.index {
                    let _ = peer.send(SchedMsg::Poke);
                }
            }
        }
    }

    /// Complete a think: record metrics and send the deferred reply.
    /// `cut` is true when the think was finished by its deadline expiry
    /// ([`Scheduler::cutoff_think`]) rather than by draining its budget.
    fn finish_think(&mut self, sid: u64, cut: bool) {
        let Some(sess) = self.sessions.get_mut(&sid) else { return };
        let Some(job) = sess.thinking.take() else { return };
        sess.driver.assert_quiescent();
        let sims = sess.driver.completed();
        sess.thinks += 1;
        sess.sims += sims as u64;
        if let Some(rem) = sess.remaining.as_mut() {
            *rem = rem.saturating_sub(sims as u64);
        }
        self.thinks += 1;
        self.sims += sims as u64;
        let elapsed_ms = self.clock.now_us().saturating_sub(job.started_us) as f64 / 1e3;
        self.think_hist.record(elapsed_ms);
        if job.deadline_us.is_some() {
            if cut {
                self.deadline_misses += 1;
            } else {
                self.deadline_hits += 1;
            }
            self.deadline_sims_hist.record(sims as f64);
        }
        let best = sess.driver.best_action();
        // Flip counter: did this think change the recommendation? A
        // flapping best action under a steady position means the sim
        // budget is too small — `inspect` surfaces the count.
        if sess.last_best.is_some_and(|prev| prev != best) {
            sess.best_flips += 1;
        }
        sess.last_best = Some(best);
        let reply = ThinkReply {
            action: best,
            value: sess.driver.root_value(),
            sims,
            tree_size: sess.driver.tree().len(),
            elapsed_ms,
            quiescent: sess.driver.tree().total_unobserved() == 0,
            remaining: sess.remaining,
            cutoff: job.deadline_us.map(|_| cut),
        };
        // Durability: the think's search progress lives only in the
        // tree, so snapshot it on the configured cadence (the crash-loss
        // window is at most `snapshot_every - 1` thinks of progress) —
        // delta-encoded against the previous snapshot while the chain is
        // short, full every `--full-every`-th time. The reply is held on
        // the snapshot's commit ticket instead of a per-record fsync —
        // once the client has seen this think's recommendation, a crash
        // must not roll the tree back behind it, but many sessions'
        // snapshots now share one fsync.
        let snapshot_due =
            self.store.is_some() && sess.thinks % self.snapshot_every as u64 == 0;
        self.journal_event(sid, 0, job.trace, EventKind::ThinkDone, sims as u64);
        let mut seq = None;
        if snapshot_due {
            match self.image_of(sid) {
                Ok(image) => seq = self.log(|s| s.log_snapshot(sid, &image)),
                Err(e) => {
                    eprintln!("shard {}: think snapshot failed: {e:#}", self.shard.index)
                }
            }
            if let Some(seq) = seq {
                self.journal_event(sid, 0, job.trace, EventKind::Snapshot, seq);
                self.journal_event(sid, 0, job.trace, EventKind::WalAppend, seq);
            }
        }
        self.reply_or_hold(seq, sid, job.trace, HeldReply::Think(job.reply, reply));
    }

    fn snapshot(&mut self) -> ServiceMetrics {
        if let Some(store) = &self.store {
            self.counters_cache = store.counters();
        }
        let sc = self.counters_cache;
        let uptime = Duration::from_micros(self.clock.now_us());
        let secs = uptime.as_secs_f64().max(1e-9);
        let mut m = ServiceMetrics {
            uptime,
            shards: 1,
            sessions_open: self.sessions.len(),
            sessions_opened: self.opened,
            sessions_closed: self.closed,
            sessions_rejected: self.rejected,
            thinks: self.thinks,
            sims: self.sims,
            sims_stolen: self.sims_stolen,
            sims_shed: self.sims_shed,
            sessions_recovered: self.recovered,
            migrations_in: self.migrations_in,
            migrations_out: self.migrations_out,
            snapshots: sc.snapshots,
            wal_records: sc.records,
            wal_batches: sc.batches,
            wal_fsyncs: sc.fsyncs,
            snapshot_bytes_full: sc.snapshot_bytes_full,
            snapshot_bytes_delta: sc.snapshot_bytes_delta,
            held_replies: self.held.len(),
            held_replies_hwm: self.held_hwm,
            held_replies_shed: self.held_shed,
            hosts: 0,
            host_unreachable: 0,
            sessions_per_sec: self.closed as f64 / secs,
            thinks_per_sec: self.thinks as f64 / secs,
            sims_per_sec: self.sims as f64 / secs,
            think_hist: self.think_hist.clone(),
            expand_hist: self.expand_hist.clone(),
            sim_hist: self.sim_hist.clone(),
            commit_hold_hist: self.commit_hold_hist.clone(),
            deadline_sims_hist: self.deadline_sims_hist.clone(),
            deadline_hits: self.deadline_hits,
            deadline_misses: self.deadline_misses,
            exp_occupancy: self.expansion.breakdown().occupancy(),
            sim_occupancy: self.simulation.breakdown().occupancy(),
            expansion_workers: self.expansion.capacity(),
            simulation_workers: self.simulation.capacity(),
            pending_expansions: self.pending_exp,
            pending_simulations: self.pending_sim,
            journal_dropped: self.journal.dropped(),
            // ΣO across every session right now (each term is the
            // driver's O(1) running counter, so this is O(sessions)).
            unobserved: self.sessions.values().map(|s| s.driver.unobserved()).sum(),
            best_flips: self.sessions.values().map(|s| s.best_flips).sum(),
            tree_corruptions: self.sessions.values().map(|s| s.driver.corruptions()).sum(),
            ..Default::default()
        };
        m.derive_latency_scalars();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;

    fn quick_spec(seed: u64) -> SearchSpec {
        SearchSpec {
            max_simulations: 16,
            rollout_limit: 8,
            max_depth: 10,
            seed,
            ..SearchSpec::default()
        }
    }

    fn garnet(seed: u64) -> Box<dyn Env> {
        Box::new(Garnet::new(15, 3, 20, 0.0, seed))
    }

    #[test]
    fn open_think_advance_close_lifecycle() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let sid = h.open(garnet(1), quick_spec(1), SessionOptions::default()).unwrap();
        let think = h.think(sid, 0).unwrap();
        assert_eq!(think.sims, 16);
        assert!(think.quiescent);
        assert!(think.tree_size > 1);
        let adv = h.advance(sid, think.action).unwrap();
        assert!(adv.reward.is_finite());
        assert_eq!(adv.steps, 1);
        let best = h.best_action(sid).unwrap();
        let _ = best; // may be the fallback on a freshly advanced tree
        let close = h.close(sid).unwrap();
        assert_eq!(close.thinks, 1);
        assert_eq!(close.sims, 16);
        assert_eq!(close.unobserved, 0);
    }

    #[test]
    fn unknown_session_errors() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let h = service.handle();
        assert!(h.think(99, 4).is_err());
        assert!(h.advance(99, 0).is_err());
        assert!(h.close(99).is_err());
    }

    #[test]
    fn lifetime_budget_clips_and_exhausts() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let opts = SessionOptions { total_sim_budget: Some(20), ..Default::default() };
        let sid = h.open(garnet(2), quick_spec(2), opts).unwrap();
        let t1 = h.think(sid, 16).unwrap();
        assert_eq!(t1.sims, 16);
        assert_eq!(t1.remaining, Some(4));
        let t2 = h.think(sid, 16).unwrap();
        assert_eq!(t2.sims, 4, "clipped to the remaining budget");
        assert_eq!(t2.remaining, Some(0));
        assert!(h.think(sid, 1).is_err(), "budget exhausted");
        h.close(sid).unwrap();
    }

    #[test]
    fn concurrent_sessions_share_the_pools() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 2,
            simulation_workers: 4,
            ..Default::default()
        });
        let n = 8;
        let mut joins = Vec::new();
        for i in 0..n {
            let h = service.handle();
            joins.push(std::thread::spawn(move || {
                let sid = h
                    .open(garnet(i as u64), quick_spec(i as u64), SessionOptions::default())
                    .unwrap();
                let mut total = 0.0;
                for _ in 0..5 {
                    let t = h.think(sid, 12).unwrap();
                    assert!(t.quiescent, "per-session ΣO must drain between thinks");
                    let adv = h.advance(sid, t.action).unwrap();
                    total += adv.reward;
                    if adv.done {
                        break;
                    }
                }
                let close = h.close(sid).unwrap();
                assert_eq!(close.unobserved, 0);
                total
            }));
        }
        for j in joins {
            assert!(j.join().unwrap().is_finite());
        }
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.sessions_opened, n);
        assert_eq!(m.sessions_closed, n);
        assert_eq!(m.sessions_open, 0);
        assert!(m.thinks >= n);
        assert!(m.sims > 0);
        assert!(m.think_ms_p99 >= m.think_ms_p50);
    }

    #[test]
    fn metrics_snapshot_is_sane_when_idle() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.sessions_open, 0);
        assert_eq!(m.pending_expansions, 0);
        assert_eq!(m.pending_simulations, 0);
        assert_eq!(m.expansion_workers, 1);
        assert_eq!(m.simulation_workers, 1);
        assert_eq!(m.sims_stolen, 0);
        assert_eq!(m.sims_shed, 0);
        assert_eq!(m.sessions_rejected, 0);
    }

    #[test]
    fn session_cap_rejects_with_typed_busy() {
        let (tx, rx) = channel::<SchedMsg>();
        let wiring = ShardWiring {
            index: 0,
            peers: vec![tx.clone()],
            steal: None,
            max_sessions: Some(2),
            store: None,
            snapshot_every: 1,
            flight: None,
        };
        let cfg = ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        };
        let service = SearchService::start_shard(cfg, wiring, tx, rx).unwrap();
        let h = service.handle();
        let a = h.open(garnet(1), quick_spec(1), SessionOptions::default()).unwrap();
        let _b = h.open(garnet(2), quick_spec(2), SessionOptions::default()).unwrap();
        let err = h
            .open(garnet(3), quick_spec(3), SessionOptions::default())
            .expect_err("third open must be rejected");
        let busy = err.downcast_ref::<Busy>().expect("typed Busy error");
        assert_eq!(busy.limit, 2);
        assert_eq!(busy.open, 2);
        // Freeing a slot re-admits.
        h.close(a).unwrap();
        let c = h.open(garnet(4), quick_spec(4), SessionOptions::default()).unwrap();
        h.close(c).unwrap();
        let m = h.metrics().unwrap();
        assert_eq!(m.sessions_rejected, 1);
    }

    #[test]
    fn explicit_session_ids_roundtrip_and_reject_duplicates() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let h = service.handle();
        let sid = h
            .open_with_id(777, garnet(5), quick_spec(5), SessionOptions::default())
            .unwrap();
        assert_eq!(sid, 777);
        assert!(
            h.open_with_id(777, garnet(6), quick_spec(6), SessionOptions::default())
                .is_err(),
            "duplicate explicit id must be rejected"
        );
        let t = h.think(777, 4).unwrap();
        assert!(t.quiescent);
        h.close(777).unwrap();
    }

    /// Deterministic park/shed driver for the held-reply cap tests: the
    /// scripted disk never syncs on its own, so replies park until the
    /// cap forces a shed. Threads hand off via the disk's pending-record
    /// count, which only the scheduler thread advances — each thread
    /// waits until the previous thread's record is appended (and its
    /// reply parked, since the scheduler handles requests one at a time)
    /// before issuing its own op.
    fn wait_pending(disk: &crate::testkit::durability::ScriptedDisk, at_least: usize) {
        while disk.pending_records() < at_least {
            std::thread::yield_now();
        }
    }

    fn capped_service(
        max_held: usize,
    ) -> (SearchService, crate::testkit::durability::ScriptedDisk) {
        let (store, disk) = crate::testkit::durability::ScriptedStore::create(1);
        let service = SearchService::start_with_store(
            ServiceConfig {
                expansion_workers: 1,
                simulation_workers: 2,
                max_held: Some(max_held),
                ..Default::default()
            },
            1,
            move || Ok((Box::new(store) as Box<dyn SessionStore>, Recovery::default())),
        )
        .unwrap();
        (service, disk)
    }

    #[test]
    fn held_reply_cap_is_never_exceeded() {
        // Cap = 1, two sessions ping-ponging: every A-op parks (held =
        // 1, the cap), every B-op sheds — the store is forced durable
        // synchronously and *both* replies deliver. The disk is never
        // synced by the test: with an uncapped queue nothing would ever
        // deliver and both threads would hang forever.
        let (service, disk) = capped_service(1);
        let rounds = 4u64;
        let ha = service.handle();
        let hb = service.handle();
        let db = disk.clone();
        let a = std::thread::spawn(move || {
            let sid = ha.open(garnet(1), quick_spec(1), SessionOptions::default()).unwrap();
            for _ in 0..rounds {
                assert!(ha.think(sid, 4).unwrap().quiescent);
            }
            ha.close(sid).unwrap();
        });
        let b = std::thread::spawn(move || {
            wait_pending(&db, 1);
            let sid = hb.open(garnet(2), quick_spec(2), SessionOptions::default()).unwrap();
            for _ in 0..rounds {
                wait_pending(&db, 1);
                assert!(hb.think(sid, 4).unwrap().quiescent);
            }
            wait_pending(&db, 1);
            hb.close(sid).unwrap();
        });
        a.join().unwrap();
        b.join().unwrap();
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.held_replies, 0, "everything released");
        assert_eq!(m.held_replies_hwm, 1, "the cap was reached but never exceeded");
        // Every B-op shed: open + `rounds` thinks + close.
        assert_eq!(m.held_replies_shed, rounds + 2);
        assert_eq!(disk.pending_records(), 0, "sheds forced everything durable");
    }

    #[test]
    fn slow_disk_burst_degrades_to_backpressure_not_queueing() {
        // A scripted slow-disk burst: three sessions hammer a disk whose
        // committer never runs. With cap = 2 the queue grows to exactly
        // the cap, then every further reply degrades to a synchronous
        // flush (backpressure) — it never queues past the cap, and the
        // flush batches the whole backlog into one scripted fsync.
        let (service, disk) = capped_service(2);
        let rounds = 3u64;
        let mut joins = Vec::new();
        for lane in 0..3u64 {
            let h = service.handle();
            let d = disk.clone();
            joins.push(std::thread::spawn(move || {
                // Lane 0 parks, lane 1 parks behind it, lane 2 sheds.
                wait_pending(&d, lane as usize);
                let sid =
                    h.open(garnet(lane), quick_spec(lane), SessionOptions::default()).unwrap();
                for _ in 0..rounds {
                    wait_pending(&d, lane as usize);
                    assert!(h.think(sid, 4).unwrap().quiescent);
                }
                wait_pending(&d, lane as usize);
                h.close(sid).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.held_replies, 0);
        assert_eq!(m.held_replies_hwm, 2, "the burst backlog is bounded by the cap");
        // One shed per round (open round + think rounds + close round),
        // each batching cap + 1 records into one scripted fsync.
        assert_eq!(m.held_replies_shed, rounds + 2);
        let (records, batches, _) = disk.counters();
        assert_eq!(records, 3 * (rounds + 2), "opens + snapshots + closes all logged");
        assert_eq!(batches, rounds + 2, "group commit held up under backpressure");
    }

    #[test]
    fn zero_budget_think_is_a_typed_rejection() {
        // A spec with max_simulations = 0 gives the session a zero
        // default, so `sims: 0` has no fallback — the 0/0 combination
        // must be rejected at admission, not admitted as a think that
        // can never finish.
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let h = service.handle();
        let spec = SearchSpec { max_simulations: 0, ..quick_spec(1) };
        let sid = h.open(garnet(1), spec, SessionOptions::default()).unwrap();
        let err = h.think(sid, 0).expect_err("0/0 think must be rejected");
        let zero = err.downcast_ref::<ZeroThink>().expect("typed ZeroThink error");
        assert_eq!(zero.session, sid);
        assert!(err.to_string().contains("no simulation budget"));
        // An explicit budget still works; the session is unharmed.
        let t = h.think(sid, 4).unwrap();
        assert_eq!(t.sims, 4);
        assert_eq!(t.cutoff, None, "plain thinks carry no cutoff flag");
        h.close(sid).unwrap();
    }

    #[test]
    fn wall_clock_deadline_cuts_and_full_budgets_hit() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let sid = h.open(garnet(6), quick_spec(6), SessionOptions::default()).unwrap();
        // A budget far beyond what 40 ms allows: the clock must cut it.
        let t = h.think_deadline(sid, 1_000_000, 40, 0).unwrap();
        assert_eq!(t.cutoff, Some(true));
        assert!(t.sims < 1_000_000);
        assert!(t.quiescent, "fold_in_flight restored quiescence at the cutoff");
        // A small budget under a generous deadline: a hit, not a cut.
        let t2 = h.think_deadline(sid, 8, 60_000, 0).unwrap();
        assert_eq!(t2.cutoff, Some(false));
        assert_eq!(t2.sims, 8);
        let m = h.metrics().unwrap();
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.deadline_hits, 1);
        assert_eq!(m.deadline_sims_hist.count(), 2);
        assert_eq!(m.tree_corruptions, 0);
        h.close(sid).unwrap();
    }

    #[test]
    fn virtual_clock_scripts_deadline_expiry_deterministically() {
        // The Clock seam: a scripted cell stands in for wall time, so
        // the test — not the OS scheduler — decides when the deadline
        // fires. The pools still run for real; only *time* is virtual.
        let cell = Arc::new(AtomicU64::new(0));
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            clock: Clock::Virtual(cell.clone()),
            ..Default::default()
        });
        let h = service.handle();
        let sid = h.open(garnet(7), quick_spec(7), SessionOptions::default()).unwrap();
        let thinker = {
            let h = h.clone();
            std::thread::spawn(move || h.think_deadline(sid, 1_000_000, 5, 0))
        };
        // Let real rollouts accumulate while virtual time stands still
        // (the 5 ms deadline cannot fire at now = 0). `sim_hist` counts
        // absorbed simulation results, so it moves mid-think.
        while h.metrics().unwrap().sim_hist.count() == 0 {
            std::thread::yield_now();
        }
        // ...then expire the clock and poke the scheduler awake.
        cell.store(10_000_000, Ordering::Relaxed);
        h.tx.send(SchedMsg::Poke).unwrap();
        let t = thinker.join().unwrap().unwrap();
        assert_eq!(t.cutoff, Some(true));
        assert!(t.sims < 1_000_000);
        assert!(t.quiescent);
        let m = h.metrics().unwrap();
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.unobserved, 0, "ΣO = 0 after the fold");
        // The cutoff left a timeline: the DeadlineCut event records how
        // many in-flight tasks were folded.
        let events = h.trace(Some(sid), 10_000).unwrap();
        assert!(events.iter().any(|e| e.kind == EventKind::DeadlineCut));
        h.close(sid).unwrap();
    }

    #[test]
    fn trace_reconstructs_a_think_timeline() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let sid = h.open(garnet(9), quick_spec(9), SessionOptions::default()).unwrap();
        let t = h.think_traced(sid, 8, 0xABCD).unwrap();
        assert!(t.quiescent);
        let events = h.trace(Some(sid), 10_000).unwrap();
        let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
        for want in [
            EventKind::SessionOpen,
            EventKind::Admit,
            EventKind::Select,
            EventKind::ExpandIssued,
            EventKind::ExpandDone,
            EventKind::SimIssued,
            EventKind::SimDone,
            EventKind::Backprop,
            EventKind::ThinkDone,
            EventKind::ReplySent,
        ] {
            assert!(kinds.contains(&want), "timeline missing {}", want.name());
        }
        // Single-writer journal: timestamps are monotone in record order.
        for w in events.windows(2) {
            assert!(w[0].at_us <= w[1].at_us, "journal went backwards in time");
        }
        // The caller's trace id rode from admit through the reply.
        let admit = events.iter().find(|e| e.kind == EventKind::Admit).unwrap();
        assert_eq!(admit.trace, 0xABCD);
        assert_eq!(admit.arg, 8, "admit records the sim budget");
        let sent = events.iter().rfind(|e| e.kind == EventKind::ReplySent).unwrap();
        assert_eq!(sent.trace, 0xABCD);
        // The histograms counted the same think.
        let m = h.metrics().unwrap();
        assert_eq!(m.think_hist.count(), 1);
        assert!(m.sim_hist.count() >= 8);
        assert!(m.expand_hist.count() >= 1);
        // A session filter excludes other sessions' events.
        let other = h.open(garnet(10), quick_spec(10), SessionOptions::default()).unwrap();
        assert!(h.trace(Some(sid), 10_000).unwrap().iter().all(|e| e.session == sid));
        h.close(other).unwrap();
        h.close(sid).unwrap();
    }
}
