//! The multi-session scheduler: many live WU-UCT searches, one shared
//! expansion pool, one shared simulation pool.
//!
//! One scheduler thread owns every session's [`SearchDriver`] plus the two
//! pools. Because the driver never blocks — it only `issue`s tasks and
//! `absorb`s results — the thread interleaves sessions freely: whenever a
//! worker slot frees up, the thinking session with the **earliest virtual
//! deadline** issues the next rollout, and each issued rollout pushes that
//! session's deadline back by its stride (1 / weight). That is classic
//! virtual-time fair scheduling: equal-weight sessions converge to equal
//! worker shares regardless of arrival order or budget size, and avoids
//! the tree-contention pitfalls of sharing one tree across threads (Liu et
//! al. 2020) — every session keeps a private tree; only *workers* are
//! shared.
//!
//! Task results are routed back by a global task-id → session map, so the
//! paper's per-tree invariant (`ΣO = 0` at quiescence, Eqs. 5–6) holds
//! per session no matter how thinks interleave — a property-tested
//! guarantee (`rust/tests/properties.rs`).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::env::Env;
use crate::eval::{HeuristicPolicy, PolicyFactory};
use crate::mcts::common::SearchSpec;
use crate::mcts::wu_uct::driver::{SearchDriver, TaskSink};
use crate::mcts::wu_uct::workers::{Pool, Task, TaskResult};
use crate::service::metrics::{LatencyStats, ServiceMetrics};

/// Shared-pool sizing and defaults for a service instance. Worker counts
/// are clamped to ≥ 1 at start (a zero-capacity pool could never serve).
#[derive(Clone)]
pub struct ServiceConfig {
    pub expansion_workers: usize,
    pub simulation_workers: usize,
    /// Rollout policy every simulation worker uses.
    pub policy: PolicyFactory,
    /// Seed for the worker pools' policy streams.
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            expansion_workers: 2,
            simulation_workers: 8,
            policy: HeuristicPolicy::factory(),
            seed: 0,
        }
    }
}

/// Per-session knobs supplied at `open`.
#[derive(Debug, Clone)]
pub struct SessionOptions {
    /// Default simulations per think (0 ⇒ the spec's `max_simulations`).
    pub think_sims: u32,
    /// Fair-share weight; a weight-2 session gets twice the worker share
    /// of a weight-1 session under contention.
    pub weight: f64,
    /// Lifetime simulation budget; thinks clip to what remains and error
    /// once it is exhausted. `None` ⇒ unlimited.
    pub total_sim_budget: Option<u64>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions { think_sims: 0, weight: 1.0, total_sim_budget: None }
    }
}

/// Reply to a completed think.
#[derive(Debug, Clone)]
pub struct ThinkReply {
    pub action: usize,
    pub value: f64,
    pub sims: u32,
    pub tree_size: usize,
    pub elapsed_ms: f64,
    /// `ΣO = 0` after the think (the paper's quiescence invariant).
    pub quiescent: bool,
    /// Lifetime simulations left, when a budget was set.
    pub remaining: Option<u64>,
}

/// Reply to an `advance`.
#[derive(Debug, Clone)]
pub struct AdvanceReply {
    pub reward: f64,
    /// Episode finished with this step.
    pub done: bool,
    /// On-path subtree (with statistics) carried over as the new root.
    pub reused: bool,
    /// Nodes retained by the carry-over.
    pub retained: usize,
    /// Environment steps taken so far in this session.
    pub steps: u64,
}

/// Reply to a `close`.
#[derive(Debug, Clone)]
pub struct CloseReply {
    pub thinks: u64,
    pub sims: u64,
    pub steps: u64,
    /// Final `ΣO` of the session's tree (must be 0; tested).
    pub unobserved: u64,
}

enum Request {
    Open {
        env: Box<dyn Env>,
        spec: SearchSpec,
        opts: SessionOptions,
        reply: Sender<u64>,
    },
    Think { session: u64, sims: u32, reply: Sender<Result<ThinkReply>> },
    Advance { session: u64, action: usize, reply: Sender<Result<AdvanceReply>> },
    Best { session: u64, reply: Sender<Result<usize>> },
    Close { session: u64, reply: Sender<Result<CloseReply>> },
    Metrics { reply: Sender<ServiceMetrics> },
    Shutdown,
}

enum SchedMsg {
    Request(Request),
    Done(TaskResult),
}

struct ThinkJob {
    reply: Sender<Result<ThinkReply>>,
    started: Instant,
}

struct Session {
    driver: SearchDriver,
    thinking: Option<ThinkJob>,
    /// Virtual deadline for fair scheduling; earliest issues next.
    deadline: f64,
    /// Deadline increment per issued rollout (1 / weight).
    stride: f64,
    default_sims: u32,
    remaining: Option<u64>,
    thinks: u64,
    sims: u64,
    steps: u64,
}

/// Cloneable client handle; every op is a blocking round-trip to the
/// scheduler thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<SchedMsg>,
}

impl ServiceHandle {
    fn roundtrip<T>(&self, req: Request, rx: Receiver<T>) -> Result<T> {
        self.tx
            .send(SchedMsg::Request(req))
            .map_err(|_| anyhow!("search service stopped"))?;
        rx.recv().map_err(|_| anyhow!("search service stopped"))
    }

    /// Open a session rooted at `env`'s current state.
    pub fn open(&self, env: Box<dyn Env>, spec: SearchSpec, opts: SessionOptions) -> Result<u64> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Open { env, spec, opts, reply: tx }, rx)
    }

    /// Run one think (`sims` = 0 ⇒ the session's default budget) and
    /// block until the search completes.
    pub fn think(&self, session: u64, sims: u32) -> Result<ThinkReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Think { session, sims, reply: tx }, rx)?
    }

    /// Execute `action` in the session's environment, reusing the on-path
    /// subtree as the new search root.
    pub fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Advance { session, action, reply: tx }, rx)?
    }

    /// Current recommended root action without searching further.
    pub fn best_action(&self, session: u64) -> Result<usize> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Best { session, reply: tx }, rx)?
    }

    pub fn close(&self, session: u64) -> Result<CloseReply> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Close { session, reply: tx }, rx)?
    }

    pub fn metrics(&self) -> Result<ServiceMetrics> {
        let (tx, rx) = channel();
        self.roundtrip(Request::Metrics { reply: tx }, rx)
    }
}

/// The service: owns the scheduler thread; dropping shuts it down.
pub struct SearchService {
    handle: ServiceHandle,
    thread: Option<JoinHandle<()>>,
}

impl SearchService {
    pub fn start(cfg: ServiceConfig) -> SearchService {
        let (tx, rx) = channel::<SchedMsg>();
        // A zero-capacity pool would gate dispatch() shut forever and hang
        // every think() caller; clamp rather than hand out a dead service.
        let n_exp = cfg.expansion_workers.max(1);
        let n_sim = cfg.simulation_workers.max(1);
        let mut expansion = Pool::new(n_exp, cfg.policy.clone(), cfg.seed ^ 0xe);
        let mut simulation = Pool::new(n_sim, cfg.policy.clone(), cfg.seed ^ 0x5);
        // Funnel both pools into the scheduler inbox so the thread blocks
        // on exactly one channel (std mpsc has no select).
        for pool in [&mut expansion, &mut simulation] {
            let results = pool.take_receiver();
            let inbox = tx.clone();
            std::thread::spawn(move || {
                while let Ok(r) = results.recv() {
                    if inbox.send(SchedMsg::Done(r)).is_err() {
                        break;
                    }
                }
            });
        }
        let thread = std::thread::spawn(move || {
            Scheduler {
                expansion,
                simulation,
                inbox: rx,
                sessions: HashMap::new(),
                routes: HashMap::new(),
                next_session: 1,
                next_task: 1,
                pending_exp: 0,
                pending_sim: 0,
                virtual_time: 0.0,
                opened: 0,
                closed: 0,
                thinks: 0,
                sims: 0,
                think_latency: LatencyStats::default(),
                started: Instant::now(),
            }
            .run()
        });
        SearchService { handle: ServiceHandle { tx }, thread: Some(thread) }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }
}

impl Drop for SearchService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(SchedMsg::Request(Request::Shutdown));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Scheduler state, owned by its thread.
struct Scheduler {
    expansion: Pool,
    simulation: Pool,
    inbox: Receiver<SchedMsg>,
    sessions: HashMap<u64, Session>,
    /// Global task id → session id.
    routes: HashMap<u64, u64>,
    next_session: u64,
    next_task: u64,
    pending_exp: usize,
    pending_sim: usize,
    virtual_time: f64,
    opened: u64,
    closed: u64,
    thinks: u64,
    sims: u64,
    think_latency: LatencyStats,
    started: Instant,
}

/// [`TaskSink`] over the shared pools for one session: allocates global
/// ids, records the route and tracks global in-flight counts.
struct SharedSink<'a> {
    expansion: &'a Pool,
    simulation: &'a Pool,
    routes: &'a mut HashMap<u64, u64>,
    next_task: &'a mut u64,
    pending_exp: &'a mut usize,
    pending_sim: &'a mut usize,
    session: u64,
}

impl SharedSink<'_> {
    fn next_id(&mut self) -> u64 {
        let id = *self.next_task;
        *self.next_task += 1;
        self.routes.insert(id, self.session);
        id
    }
}

impl TaskSink for SharedSink<'_> {
    fn submit_expand(&mut self, env: Box<dyn Env>, action: usize, max_width: usize) -> u64 {
        let id = self.next_id();
        self.expansion.submit(Task::Expand { task_id: id, env, action, max_width });
        *self.pending_exp += 1;
        id
    }

    fn submit_simulate(&mut self, env: Box<dyn Env>, gamma: f64, limit: u32) -> u64 {
        let id = self.next_id();
        self.simulation.submit(Task::Simulate { task_id: id, env, gamma, limit });
        *self.pending_sim += 1;
        id
    }
}

impl Scheduler {
    fn run(mut self) {
        loop {
            let msg = match self.inbox.recv() {
                Ok(m) => m,
                Err(_) => return, // every handle dropped
            };
            if !self.handle_msg(msg) {
                return;
            }
            // Drain whatever else queued up before refilling the pools.
            while let Ok(m) = self.inbox.try_recv() {
                if !self.handle_msg(m) {
                    return;
                }
            }
            self.dispatch();
        }
    }

    /// Returns false on shutdown.
    fn handle_msg(&mut self, msg: SchedMsg) -> bool {
        match msg {
            SchedMsg::Request(req) => self.handle_request(req),
            SchedMsg::Done(result) => {
                self.handle_result(result);
                true
            }
        }
    }

    fn handle_request(&mut self, req: Request) -> bool {
        match req {
            Request::Open { env, spec, opts, reply } => {
                let id = self.next_session;
                self.next_session += 1;
                let default_sims = if opts.think_sims > 0 {
                    opts.think_sims
                } else {
                    spec.max_simulations
                };
                let session = Session {
                    driver: SearchDriver::new(spec, env.as_ref()),
                    thinking: None,
                    deadline: self.virtual_time,
                    stride: 1.0 / opts.weight.max(1e-6),
                    default_sims,
                    remaining: opts.total_sim_budget,
                    thinks: 0,
                    sims: 0,
                    steps: 0,
                };
                self.sessions.insert(id, session);
                self.opened += 1;
                let _ = reply.send(id);
            }
            Request::Think { session, sims, reply } => {
                match self.begin_think(session, sims, &reply) {
                    Ok(()) => {}
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Request::Advance { session, action, reply } => {
                let _ = reply.send(self.do_advance(session, action));
            }
            Request::Best { session, reply } => {
                let _ = reply.send(
                    self.idle_session(session).map(|s| s.driver.best_action()),
                );
            }
            Request::Close { session, reply } => {
                let _ = reply.send(self.do_close(session));
            }
            Request::Metrics { reply } => {
                let _ = reply.send(self.snapshot());
            }
            Request::Shutdown => return false,
        }
        true
    }

    /// Start a think; the reply is deferred until the budget drains.
    fn begin_think(
        &mut self,
        sid: u64,
        sims: u32,
        reply: &Sender<Result<ThinkReply>>,
    ) -> Result<()> {
        let virtual_time = self.virtual_time;
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        if sess.thinking.is_some() {
            bail!("session {sid} already has a think in flight");
        }
        let mut budget = if sims > 0 { sims } else { sess.default_sims };
        if let Some(rem) = sess.remaining {
            if rem == 0 {
                bail!("session {sid} has exhausted its simulation budget");
            }
            budget = budget.min(rem.min(u32::MAX as u64) as u32);
        }
        sess.driver.begin(budget);
        // A session that was idle re-enters the race at the current
        // virtual time (it must not hoard credit accrued while idle).
        sess.deadline = sess.deadline.max(virtual_time);
        sess.thinking = Some(ThinkJob { reply: reply.clone(), started: Instant::now() });
        if sess.driver.done() {
            self.finish_think(sid);
        }
        Ok(())
    }

    fn do_advance(&mut self, sid: u64, action: usize) -> Result<AdvanceReply> {
        let sess = self.idle_session(sid)?;
        let out = sess.driver.advance(action)?;
        sess.steps += 1;
        Ok(AdvanceReply {
            reward: out.step.reward,
            done: out.step.done || sess.driver.env().is_terminal(),
            reused: out.reused,
            retained: out.retained,
            steps: sess.steps,
        })
    }

    fn do_close(&mut self, sid: u64) -> Result<CloseReply> {
        self.idle_session(sid)?; // reject while a think is in flight
        let sess = self.sessions.remove(&sid).expect("checked above");
        self.closed += 1;
        Ok(CloseReply {
            thinks: sess.thinks,
            sims: sess.sims,
            steps: sess.steps,
            unobserved: sess.driver.tree().total_unobserved(),
        })
    }

    /// The session, provided it exists and has no think in flight.
    fn idle_session(&mut self, sid: u64) -> Result<&mut Session> {
        let sess = self
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        if sess.thinking.is_some() {
            bail!("session {sid} has a think in flight");
        }
        Ok(sess)
    }

    /// Route a pool result to its session and absorb it.
    fn handle_result(&mut self, result: TaskResult) {
        let task_id = match &result {
            TaskResult::Expanded(r) => r.task_id,
            TaskResult::Simulated(r) => r.task_id,
        };
        match &result {
            TaskResult::Expanded(_) => self.pending_exp -= 1,
            TaskResult::Simulated(_) => self.pending_sim -= 1,
        }
        let Some(sid) = self.routes.remove(&task_id) else {
            // Session vanished mid-flight (cannot happen: close requires
            // quiescence) — drop defensively rather than poison the loop.
            return;
        };
        let Some(sess) = self.sessions.get_mut(&sid) else { return };
        let mut sink = SharedSink {
            expansion: &self.expansion,
            simulation: &self.simulation,
            routes: &mut self.routes,
            next_task: &mut self.next_task,
            pending_exp: &mut self.pending_exp,
            pending_sim: &mut self.pending_sim,
            session: sid,
        };
        sess.driver.absorb(result, &mut sink);
        if sess.thinking.is_some() && sess.driver.done() {
            self.finish_think(sid);
        }
    }

    /// Fill free worker slots: repeatedly let the thinking session with
    /// the earliest virtual deadline issue one rollout.
    fn dispatch(&mut self) {
        loop {
            // A rollout's kind is unknown until selection runs, so the
            // gate cannot be exact per pool. Requiring headroom in BOTH
            // pools (the dedicated master's gate) would let a saturated
            // 2-worker expansion pool stall simulation-bound rollouts for
            // every session and idle the whole simulation fleet. Instead:
            // always require a free simulation slot (every rollout ends
            // in a simulation), and let the expansion backlog run ahead
            // of its pool by at most the free simulation capacity —
            // bounded in-flight work without cross-pool head-of-line
            // blocking.
            let free_sim = self.simulation.capacity().saturating_sub(self.pending_sim);
            if free_sim == 0 || self.pending_exp >= self.expansion.capacity() + free_sim {
                return;
            }
            let Some(sid) = self
                .sessions
                .iter()
                .filter(|(_, s)| s.thinking.is_some() && s.driver.can_issue())
                .min_by(|a, b| {
                    a.1.deadline
                        .partial_cmp(&b.1.deadline)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(&id, _)| id)
            else {
                return;
            };
            let sess = self.sessions.get_mut(&sid).expect("picked above");
            self.virtual_time = sess.deadline;
            sess.deadline += sess.stride;
            let mut sink = SharedSink {
                expansion: &self.expansion,
                simulation: &self.simulation,
                routes: &mut self.routes,
                next_task: &mut self.next_task,
                pending_exp: &mut self.pending_exp,
                pending_sim: &mut self.pending_sim,
                session: sid,
            };
            sess.driver.issue(&mut sink);
            // Terminal short-circuits can complete a think synchronously.
            if sess.driver.done() {
                self.finish_think(sid);
            }
        }
    }

    /// Complete a think: record metrics and send the deferred reply.
    fn finish_think(&mut self, sid: u64) {
        let Some(sess) = self.sessions.get_mut(&sid) else { return };
        let Some(job) = sess.thinking.take() else { return };
        sess.driver.assert_quiescent();
        let sims = sess.driver.completed();
        sess.thinks += 1;
        sess.sims += sims as u64;
        if let Some(rem) = sess.remaining.as_mut() {
            *rem = rem.saturating_sub(sims as u64);
        }
        self.thinks += 1;
        self.sims += sims as u64;
        let elapsed = job.started.elapsed();
        self.think_latency.record(elapsed);
        let reply = ThinkReply {
            action: sess.driver.best_action(),
            value: sess.driver.root_value(),
            sims,
            tree_size: sess.driver.tree().len(),
            elapsed_ms: elapsed.as_secs_f64() * 1e3,
            quiescent: sess.driver.tree().total_unobserved() == 0,
            remaining: sess.remaining,
        };
        let _ = job.reply.send(Ok(reply));
    }

    fn snapshot(&self) -> ServiceMetrics {
        let uptime = self.started.elapsed();
        let secs = uptime.as_secs_f64().max(1e-9);
        let (think_ms_mean, think_ms_p50, think_ms_p90, think_ms_p99) =
            self.think_latency.summary_ms();
        ServiceMetrics {
            uptime,
            sessions_open: self.sessions.len(),
            sessions_opened: self.opened,
            sessions_closed: self.closed,
            thinks: self.thinks,
            sims: self.sims,
            sessions_per_sec: self.closed as f64 / secs,
            thinks_per_sec: self.thinks as f64 / secs,
            sims_per_sec: self.sims as f64 / secs,
            think_ms_mean,
            think_ms_p50,
            think_ms_p90,
            think_ms_p99,
            exp_occupancy: self.expansion.breakdown().occupancy(),
            sim_occupancy: self.simulation.breakdown().occupancy(),
            expansion_workers: self.expansion.capacity(),
            simulation_workers: self.simulation.capacity(),
            pending_expansions: self.pending_exp,
            pending_simulations: self.pending_sim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;

    fn quick_spec(seed: u64) -> SearchSpec {
        SearchSpec {
            max_simulations: 16,
            rollout_limit: 8,
            max_depth: 10,
            seed,
            ..SearchSpec::default()
        }
    }

    fn garnet(seed: u64) -> Box<dyn Env> {
        Box::new(Garnet::new(15, 3, 20, 0.0, seed))
    }

    #[test]
    fn open_think_advance_close_lifecycle() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let sid = h.open(garnet(1), quick_spec(1), SessionOptions::default()).unwrap();
        let think = h.think(sid, 0).unwrap();
        assert_eq!(think.sims, 16);
        assert!(think.quiescent);
        assert!(think.tree_size > 1);
        let adv = h.advance(sid, think.action).unwrap();
        assert!(adv.reward.is_finite());
        assert_eq!(adv.steps, 1);
        let best = h.best_action(sid).unwrap();
        let _ = best; // may be the fallback on a freshly advanced tree
        let close = h.close(sid).unwrap();
        assert_eq!(close.thinks, 1);
        assert_eq!(close.sims, 16);
        assert_eq!(close.unobserved, 0);
    }

    #[test]
    fn unknown_session_errors() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let h = service.handle();
        assert!(h.think(99, 4).is_err());
        assert!(h.advance(99, 0).is_err());
        assert!(h.close(99).is_err());
    }

    #[test]
    fn lifetime_budget_clips_and_exhausts() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let h = service.handle();
        let opts = SessionOptions { total_sim_budget: Some(20), ..Default::default() };
        let sid = h.open(garnet(2), quick_spec(2), opts).unwrap();
        let t1 = h.think(sid, 16).unwrap();
        assert_eq!(t1.sims, 16);
        assert_eq!(t1.remaining, Some(4));
        let t2 = h.think(sid, 16).unwrap();
        assert_eq!(t2.sims, 4, "clipped to the remaining budget");
        assert_eq!(t2.remaining, Some(0));
        assert!(h.think(sid, 1).is_err(), "budget exhausted");
        h.close(sid).unwrap();
    }

    #[test]
    fn concurrent_sessions_share_the_pools() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 2,
            simulation_workers: 4,
            ..Default::default()
        });
        let n = 8;
        let mut joins = Vec::new();
        for i in 0..n {
            let h = service.handle();
            joins.push(std::thread::spawn(move || {
                let sid = h
                    .open(garnet(i as u64), quick_spec(i as u64), SessionOptions::default())
                    .unwrap();
                let mut total = 0.0;
                for _ in 0..5 {
                    let t = h.think(sid, 12).unwrap();
                    assert!(t.quiescent, "per-session ΣO must drain between thinks");
                    let adv = h.advance(sid, t.action).unwrap();
                    total += adv.reward;
                    if adv.done {
                        break;
                    }
                }
                let close = h.close(sid).unwrap();
                assert_eq!(close.unobserved, 0);
                total
            }));
        }
        for j in joins {
            assert!(j.join().unwrap().is_finite());
        }
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.sessions_opened, n);
        assert_eq!(m.sessions_closed, n);
        assert_eq!(m.sessions_open, 0);
        assert!(m.thinks >= n);
        assert!(m.sims > 0);
        assert!(m.think_ms_p99 >= m.think_ms_p50);
    }

    #[test]
    fn metrics_snapshot_is_sane_when_idle() {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..Default::default()
        });
        let m = service.handle().metrics().unwrap();
        assert_eq!(m.sessions_open, 0);
        assert_eq!(m.pending_expansions, 0);
        assert_eq!(m.pending_simulations, 0);
        assert_eq!(m.expansion_workers, 1);
        assert_eq!(m.simulation_workers, 1);
    }
}
