//! Consistent-hash session placement, with a migration override table.
//!
//! The sharded service routes every session op statelessly: the shard
//! owning session `s` is a pure function of `s`, so no routing table has
//! to be kept coherent across handles. The cross-process router tier
//! ([`crate::service::router`]) reuses the same ring verbatim with
//! "shard" meaning "remote host" — one placement component, two radii.
//! The classic hash-ring construction
//! (Karger et al., 1997) is used so that changing the shard count moves
//! only the sessions that land on the new/removed shard's arc — every
//! other session's placement is untouched (property-tested below).
//!
//! Each shard owns `replicas` pseudo-random points on a `u64` ring; a key
//! hashes to a point and is owned by the first shard point at or after it
//! (wrapping). Placement is fully deterministic: two rings built with the
//! same parameters place every key identically, which the shard golden
//! traces rely on.
//!
//! **Overrides** are the one deliberate exception to pure-function
//! routing: live migration ([`crate::store::migrate`]) moves a session
//! off its ring-assigned home, and the override table records the new
//! owner. [`HashRing::place`] consults it first; [`HashRing::home`]
//! ignores it (recovery uses `home` to detect which replayed sessions
//! need their overrides re-established). Construction is fallible with a
//! typed [`RingError`] — a zero-shard ring used to be an implicit
//! assert, which a config path could turn into a panic.

use std::collections::HashMap;

use crate::util::rng::SplitMix64;

/// Typed construction/override failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RingError {
    /// A ring needs at least one shard.
    NoShards,
    /// A shard needs at least one ring point.
    NoReplicas,
    /// Override target beyond the shard count.
    ShardOutOfRange { shard: usize, shards: usize },
}

impl std::fmt::Display for RingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RingError::NoShards => write!(f, "a hash ring needs at least one shard"),
            RingError::NoReplicas => write!(f, "a shard needs at least one ring point"),
            RingError::ShardOutOfRange { shard, shards } => {
                write!(f, "shard {shard} out of range (ring has {shards})")
            }
        }
    }
}

impl std::error::Error for RingError {}

/// One SplitMix64 step: a well-mixed 64-bit hash of `x`.
fn mix(x: u64) -> u64 {
    SplitMix64::new(x).next_u64()
}

/// A consistent-hash ring over `shards` shards.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// (ring position, shard index), sorted by position.
    points: Vec<(u64, usize)>,
    shards: usize,
    /// Migrated sessions: key → shard, consulted before the ring.
    overrides: HashMap<u64, usize>,
}

impl HashRing {
    /// Default virtual points per shard: enough to keep the largest arc
    /// within a few percent of ideal at small shard counts.
    pub const DEFAULT_REPLICAS: usize = 64;

    pub fn new(shards: usize, replicas: usize) -> Result<HashRing, RingError> {
        if shards == 0 {
            return Err(RingError::NoShards);
        }
        if replicas == 0 {
            return Err(RingError::NoReplicas);
        }
        let mut points = Vec::with_capacity(shards * replicas);
        for shard in 0..shards {
            for replica in 0..replicas {
                // Distinct, deterministic point per (shard, replica).
                let h = mix(((shard as u64) << 32) ^ replica as u64 ^ 0x5ea7_11e5);
                points.push((h, shard));
            }
        }
        points.sort_unstable();
        // 64-bit collisions are astronomically unlikely; keep the first
        // deterministically if one ever occurs.
        points.dedup_by_key(|&mut (h, _)| h);
        Ok(HashRing { points, shards, overrides: HashMap::new() })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard owning `key`: the override table first (migrated
    /// sessions), then the pure ring function.
    pub fn place(&self, key: u64) -> usize {
        if let Some(&shard) = self.overrides.get(&key) {
            return shard;
        }
        self.home(key)
    }

    /// The ring-assigned home of `key`, ignoring overrides. Recovery
    /// compares this against where a session actually replayed to
    /// re-establish the override table after a restart.
    pub fn home(&self, key: u64) -> usize {
        let h = mix(key);
        // First point at or after h, wrapping to the ring start.
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let (_, shard) = self.points[idx % self.points.len()];
        shard
    }

    /// Record that `key` now lives on `shard` (a completed migration).
    pub fn set_override(&mut self, key: u64, shard: usize) -> Result<(), RingError> {
        if shard >= self.shards {
            return Err(RingError::ShardOutOfRange { shard, shards: self.shards });
        }
        if shard == self.home(key) {
            // Moving home again: the ring already says so.
            self.overrides.remove(&key);
        } else {
            self.overrides.insert(key, shard);
        }
        Ok(())
    }

    /// Drop `key`'s override (session closed); returns whether one existed.
    pub fn clear_override(&mut self, key: u64) -> bool {
        self.overrides.remove(&key).is_some()
    }

    /// Keep only the overrides whose key satisfies `keep` — liveness GC
    /// for routing tiers where a close's success reply can be lost (the
    /// override of an already-closed session would otherwise survive
    /// forever). Callers pass "is this session still open anywhere".
    pub fn retain_overrides(&mut self, mut keep: impl FnMut(u64) -> bool) {
        self.overrides.retain(|&key, _| keep(key));
    }

    /// Live override count (bounded by open migrated sessions; cleared
    /// at close so the table cannot grow without bound).
    pub fn override_count(&self) -> usize {
        self.overrides.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic() {
        let a = HashRing::new(4, HashRing::DEFAULT_REPLICAS).unwrap();
        let b = HashRing::new(4, HashRing::DEFAULT_REPLICAS).unwrap();
        for key in 0..1000u64 {
            assert_eq!(a.place(key), b.place(key));
        }
        assert_eq!(a.shards(), 4);
    }

    #[test]
    fn zero_shards_and_zero_replicas_are_typed_errors() {
        assert_eq!(HashRing::new(0, 8).unwrap_err(), RingError::NoShards);
        assert_eq!(HashRing::new(3, 0).unwrap_err(), RingError::NoReplicas);
        assert!(RingError::NoShards.to_string().contains("at least one shard"));
    }

    #[test]
    fn every_shard_gets_a_fair_arc() {
        let ring = HashRing::new(4, HashRing::DEFAULT_REPLICAS).unwrap();
        let mut counts = [0usize; 4];
        let n = 20_000u64;
        for key in 0..n {
            counts[ring.place(key)] += 1;
        }
        let ideal = n as usize / 4;
        for (shard, &c) in counts.iter().enumerate() {
            assert!(
                c > ideal / 3 && c < ideal * 5 / 2,
                "shard {shard} got {c} of {n} keys (ideal {ideal})"
            );
        }
    }

    #[test]
    fn single_shard_takes_everything() {
        let ring = HashRing::new(1, 8).unwrap();
        for key in 0..100u64 {
            assert_eq!(ring.place(key), 0);
        }
    }

    #[test]
    fn growing_the_ring_only_moves_keys_to_the_new_shard() {
        // The consistent-hashing contract: adding shard 4 to a 4-shard
        // ring either leaves a key where it was or moves it to shard 4.
        let before = HashRing::new(4, HashRing::DEFAULT_REPLICAS).unwrap();
        let after = HashRing::new(5, HashRing::DEFAULT_REPLICAS).unwrap();
        let mut moved = 0usize;
        let n = 10_000u64;
        for key in 0..n {
            let (b, a) = (before.place(key), after.place(key));
            if b != a {
                assert_eq!(a, 4, "key {key} moved {b}->{a}, not to the new shard");
                moved += 1;
            }
        }
        // Roughly 1/5 of keys should move; certainly not none or all.
        assert!(moved > 0, "growing the ring moved nothing");
        assert!(moved < n as usize / 2, "growing the ring moved {moved} of {n}");
    }

    #[test]
    fn overrides_beat_the_ring_until_cleared() {
        let mut ring = HashRing::new(4, HashRing::DEFAULT_REPLICAS).unwrap();
        let key = 12345;
        let home = ring.home(key);
        let target = (home + 1) % 4;
        ring.set_override(key, target).unwrap();
        assert_eq!(ring.place(key), target);
        assert_eq!(ring.home(key), home, "home ignores overrides");
        assert_eq!(ring.override_count(), 1);
        assert!(ring.clear_override(key));
        assert_eq!(ring.place(key), home);
        assert!(!ring.clear_override(key), "second clear is a no-op");
    }

    #[test]
    fn override_back_home_clears_itself() {
        let mut ring = HashRing::new(3, 16).unwrap();
        let key = 777;
        let home = ring.home(key);
        ring.set_override(key, (home + 1) % 3).unwrap();
        assert_eq!(ring.override_count(), 1);
        // Migrating back to the ring-assigned home needs no table entry.
        ring.set_override(key, home).unwrap();
        assert_eq!(ring.override_count(), 0);
        assert_eq!(ring.place(key), home);
    }

    #[test]
    fn override_to_unknown_shard_is_rejected() {
        let mut ring = HashRing::new(2, 8).unwrap();
        assert_eq!(
            ring.set_override(1, 5).unwrap_err(),
            RingError::ShardOutOfRange { shard: 5, shards: 2 }
        );
        assert_eq!(ring.override_count(), 0);
    }
}
