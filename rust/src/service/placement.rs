//! Consistent-hash session placement.
//!
//! The sharded service routes every session op statelessly: the shard
//! owning session `s` is a pure function of `s`, so no routing table has
//! to be kept coherent across handles. The classic hash-ring construction
//! (Karger et al., 1997) is used so that changing the shard count moves
//! only the sessions that land on the new/removed shard's arc — every
//! other session's placement is untouched (property-tested below).
//!
//! Each shard owns `replicas` pseudo-random points on a `u64` ring; a key
//! hashes to a point and is owned by the first shard point at or after it
//! (wrapping). Placement is fully deterministic: two rings built with the
//! same parameters place every key identically, which the shard golden
//! traces rely on.

use crate::util::rng::SplitMix64;

/// One SplitMix64 step: a well-mixed 64-bit hash of `x`.
fn mix(x: u64) -> u64 {
    SplitMix64::new(x).next_u64()
}

/// A consistent-hash ring over `shards` shards.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// (ring position, shard index), sorted by position.
    points: Vec<(u64, usize)>,
    shards: usize,
}

impl HashRing {
    /// Default virtual points per shard: enough to keep the largest arc
    /// within a few percent of ideal at small shard counts.
    pub const DEFAULT_REPLICAS: usize = 64;

    pub fn new(shards: usize, replicas: usize) -> HashRing {
        assert!(shards >= 1, "a ring needs at least one shard");
        assert!(replicas >= 1, "a shard needs at least one ring point");
        let mut points = Vec::with_capacity(shards * replicas);
        for shard in 0..shards {
            for replica in 0..replicas {
                // Distinct, deterministic point per (shard, replica).
                let h = mix(((shard as u64) << 32) ^ replica as u64 ^ 0x5ea7_11e5);
                points.push((h, shard));
            }
        }
        points.sort_unstable();
        // 64-bit collisions are astronomically unlikely; keep the first
        // deterministically if one ever occurs.
        points.dedup_by_key(|&mut (h, _)| h);
        HashRing { points, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard owning `key` (any u64 — session ids here).
    pub fn place(&self, key: u64) -> usize {
        let h = mix(key);
        // First point at or after h, wrapping to the ring start.
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let (_, shard) = self.points[idx % self.points.len()];
        shard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic() {
        let a = HashRing::new(4, HashRing::DEFAULT_REPLICAS);
        let b = HashRing::new(4, HashRing::DEFAULT_REPLICAS);
        for key in 0..1000u64 {
            assert_eq!(a.place(key), b.place(key));
        }
        assert_eq!(a.shards(), 4);
    }

    #[test]
    fn every_shard_gets_a_fair_arc() {
        let ring = HashRing::new(4, HashRing::DEFAULT_REPLICAS);
        let mut counts = [0usize; 4];
        let n = 20_000u64;
        for key in 0..n {
            counts[ring.place(key)] += 1;
        }
        let ideal = n as usize / 4;
        for (shard, &c) in counts.iter().enumerate() {
            assert!(
                c > ideal / 3 && c < ideal * 5 / 2,
                "shard {shard} got {c} of {n} keys (ideal {ideal})"
            );
        }
    }

    #[test]
    fn single_shard_takes_everything() {
        let ring = HashRing::new(1, 8);
        for key in 0..100u64 {
            assert_eq!(ring.place(key), 0);
        }
    }

    #[test]
    fn growing_the_ring_only_moves_keys_to_the_new_shard() {
        // The consistent-hashing contract: adding shard 4 to a 4-shard
        // ring either leaves a key where it was or moves it to shard 4.
        let before = HashRing::new(4, HashRing::DEFAULT_REPLICAS);
        let after = HashRing::new(5, HashRing::DEFAULT_REPLICAS);
        let mut moved = 0usize;
        let n = 10_000u64;
        for key in 0..n {
            let (b, a) = (before.place(key), after.place(key));
            if b != a {
                assert_eq!(a, 4, "key {key} moved {b}->{a}, not to the new shard");
                moved += 1;
            }
        }
        // Roughly 1/5 of keys should move; certainly not none or all.
        assert!(moved > 0, "growing the ring moved nothing");
        assert!(moved < n as usize / 2, "growing the ring moved {moved} of {n}");
    }
}
