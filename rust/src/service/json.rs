//! Minimal JSON encode/parse (no serde in the offline crate cache).
//!
//! Covers the full value grammar the wire protocol needs — objects,
//! arrays, strings with escapes, f64 numbers, booleans, null — with a
//! recursive-descent parser and a compact serializer. Object fields keep
//! insertion order so responses render deterministically.
//!
//! Malformed input can never panic: every failure is a typed
//! [`ParseError`] carrying the byte offset, including invalid UTF-8 via
//! [`Json::parse_bytes`] (the TCP server feeds raw lines through it so a
//! garbage client cannot kill its connection handler).

use std::fmt::Write as _;

/// Typed parse failure; `at` is a byte offset into the input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    /// Input is not valid UTF-8; `at` is the first invalid byte.
    InvalidUtf8 { at: usize },
    /// Structurally malformed document (`what` names the expectation).
    Syntax { at: usize, what: &'static str },
    /// Document ended before the value did.
    Truncated { what: &'static str },
    /// A valid document followed by trailing bytes.
    Trailing { at: usize },
    /// Unparseable number literal.
    BadNumber { at: usize },
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::InvalidUtf8 { at } => write!(f, "invalid utf-8 at byte {at}"),
            ParseError::Syntax { at, what } => write!(f, "expected {what} at byte {at}"),
            ParseError::Truncated { what } => write!(f, "truncated input ({what})"),
            ParseError::Trailing { at } => write!(f, "trailing characters at byte {at}"),
            ParseError::BadNumber { at } => write!(f, "bad number at byte {at}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field names, in document order (empty for non-objects).
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(ParseError::Trailing { at: p.pos });
        }
        Ok(v)
    }

    /// Parse raw bytes: invalid UTF-8 is a typed error, never a panic —
    /// the entry point for untrusted wire input.
    pub fn parse_bytes(bytes: &[u8]) -> Result<Json, ParseError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| ParseError::InvalidUtf8 { at: e.valid_up_to() })?;
        Json::parse(text)
    }
}

/// Builder sugar: `obj([("ok", Json::Bool(true)), ...])`.
pub fn obj<const N: usize>(fields: [(&str, Json); N]) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8, what: &'static str) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(ParseError::Syntax { at: self.pos, what })
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(ParseError::Syntax { at: self.pos, what: "literal" })
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(_) => Err(ParseError::Syntax { at: self.pos, what: "a JSON value" }),
            None => Err(ParseError::Truncated { what: "a JSON value" }),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{', "'{'")?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "':'")?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                Some(_) => return Err(ParseError::Syntax { at: self.pos, what: "',' or '}'" }),
                None => return Err(ParseError::Truncated { what: "object" }),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[', "'['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                Some(_) => return Err(ParseError::Syntax { at: self.pos, what: "',' or ']'" }),
                None => return Err(ParseError::Truncated { what: "array" }),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"', "'\"'")?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(ParseError::Truncated { what: "string" });
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(ParseError::Truncated { what: "escape" });
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(ParseError::Truncated { what: "\\u escape" });
                            }
                            let hex = &self.bytes[self.pos..self.pos + 4];
                            let hex = std::str::from_utf8(hex).map_err(|_| {
                                ParseError::Syntax { at: self.pos, what: "4 hex digits" }
                            })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| {
                                ParseError::Syntax { at: self.pos, what: "4 hex digits" }
                            })?;
                            self.pos += 4;
                            // Surrogates are replaced (the protocol never
                            // emits them).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => {
                            return Err(ParseError::Syntax {
                                at: self.pos,
                                what: "a valid escape",
                            })
                        }
                    }
                }
                _ => {
                    // Multibyte UTF-8: copy the whole char. The input is a
                    // &str, so boundaries are guaranteed valid.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .expect("parse input is valid UTF-8");
                    let c = s.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii number bytes");
        let n: f64 = text
            .parse()
            .map_err(|_| ParseError::BadNumber { at: start })?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested_document() {
        let doc = obj([
            ("op", Json::Str("think".into())),
            ("session", Json::Num(42.0)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("nested", obj([("x", Json::Num(-1.5))])),
        ]);
        let text = doc.render();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\\n\" : \"q\\\"\\u0041\" , \"b\" : [ 1 , 2.5 ] } ").unwrap();
        assert_eq!(v.get("a\n").unwrap().as_str(), Some("q\"A"));
        let Json::Arr(items) = v.get("b").unwrap() else { panic!("array") };
        assert_eq!(items[1].as_f64(), Some(2.5));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(64.0).render(), "64");
        assert_eq!(Json::Num(0.25).render(), "0.25");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn typed_getters() {
        let v = Json::parse(r#"{"n":7,"neg":-3,"f":1.5,"s":"hi","t":true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-3.0));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("t").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
        assert_eq!(v.keys(), vec!["n", "neg", "f", "s", "t"]);
        assert!(Json::Null.keys().is_empty());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "tru", "\"open", "{} extra", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn truncated_inputs_give_typed_truncation_or_syntax_errors() {
        // Every prefix of a valid document parses to an error, never a
        // panic, and EOF-shaped failures are Truncated.
        let full = r#"{"op":"think","session":12,"flags":[true,null],"x":1.5}"#;
        for cut in 0..full.len() {
            let prefix = &full[..cut];
            assert!(Json::parse(prefix).is_err(), "accepted prefix {prefix:?}");
        }
        assert_eq!(
            Json::parse("{\"a\":\"unterminated"),
            Err(ParseError::Truncated { what: "string" })
        );
        assert_eq!(
            Json::parse("[1, 2"),
            Err(ParseError::Truncated { what: "array" })
        );
        assert_eq!(Json::parse(""), Err(ParseError::Truncated { what: "a JSON value" }));
    }

    #[test]
    fn parse_bytes_reports_invalid_utf8_without_panicking() {
        // 0xFF can never appear in UTF-8.
        let bad = [b'{', b'"', b'a', 0xFF, b'"', b':', b'1', b'}'];
        match Json::parse_bytes(&bad) {
            Err(ParseError::InvalidUtf8 { at }) => assert_eq!(at, 3),
            other => panic!("expected InvalidUtf8, got {other:?}"),
        }
        // Truncated multibyte sequence at the very end.
        let truncated = "{\"g\":\"é".as_bytes();
        let cut = &truncated[..truncated.len() - 1];
        assert!(matches!(
            Json::parse_bytes(cut),
            Err(ParseError::InvalidUtf8 { .. }) | Err(ParseError::Truncated { .. })
        ));
        // Valid bytes still parse.
        assert!(Json::parse_bytes(br#"{"a":1}"#).is_ok());
    }

    #[test]
    fn trailing_and_bad_escape_positions_are_reported() {
        assert_eq!(Json::parse("{} extra"), Err(ParseError::Trailing { at: 3 }));
        assert!(matches!(
            Json::parse(r#""bad \q escape""#),
            Err(ParseError::Syntax { .. })
        ));
        assert!(matches!(
            Json::parse(r#""\u00"#),
            Err(ParseError::Truncated { .. })
        ));
        assert!(matches!(
            Json::parse(r#""\uZZZZ""#),
            Err(ParseError::Syntax { .. })
        ));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("{\"g\":\"héllo ☃\"}").unwrap();
        assert_eq!(v.get("g").unwrap().as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }
}
