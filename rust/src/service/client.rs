//! Pooled line-protocol clients to remote shard hosts.
//!
//! The router tier ([`crate::service::router`]) proxies every session op
//! to the owning host over TCP. One [`HostClient`] per host keeps a pool
//! of idle connections: a call checks one out (or dials), does one
//! request/reply line round trip, and returns the connection to the
//! pool. A connection that fails mid-call is dropped and the call
//! retried once on a fresh dial; if the dial fails too, the typed
//! [`HostUnreachable`] error surfaces — the router counts these in its
//! `host_unreachable` metric and the caller decides whether the failure
//! aborts a migration handshake or just this op.
//!
//! Error mapping: remote error replies are rebuilt into the same typed
//! errors the in-process path raises — `"busy":true` becomes
//! [`Busy`](crate::service::scheduler::Busy), `"recovering":true`
//! becomes [`Recovering`](crate::store::migrate::Recovering) — with the
//! remote message attached as context, so retry logic upstream (clients,
//! the load generator, the rebalancer) cannot tell a remote shard from a
//! local one.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, Context as _, Result};

use crate::mcts::common::SearchSpec;
use crate::obs::Event;
use crate::service::frame::{BlobOrReply, FrameStream, OP_REQ};
use crate::service::json::Json;
use crate::service::metrics::ServiceMetrics;
use crate::service::proto::{event_from_json, metrics_from_json, summary_from_json};
use crate::service::fair::QosClass;
use crate::service::lease::LeaseLost;
use crate::service::scheduler::{
    AdvanceReply, Busy, CloseReply, SessionOptions, SessionStat, ThinkReply, ZeroThink,
};
use crate::service::{PromoteReply, ReplShardStatus};
use crate::store::migrate::Recovering;

/// Typed connectivity failure: the host did not answer (dial refused,
/// connection reset, or EOF mid-reply). Distinct from a remote *error
/// reply*, which means the host is alive and said no.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostUnreachable {
    pub host: String,
}

impl std::fmt::Display for HostUnreachable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "host {} is unreachable", self.host)
    }
}

impl std::error::Error for HostUnreachable {}

/// One remote host's `health` reply, parsed.
#[derive(Debug, Clone)]
pub struct RemoteHealth {
    pub role: String,
    pub shards: usize,
    pub sessions_open: usize,
    /// Open sessions with progress counters, ascending by id.
    pub sessions: Vec<SessionStat>,
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Outcome of one round-trip attempt on one connection.
enum Attempt {
    Reply(String),
    /// The request never left this process; always safe to retry.
    WriteFailed,
    /// The request may have been delivered and executed; only
    /// idempotent requests may retry.
    ReadFailed,
}

/// A pooled line-protocol client to one shard host.
pub struct HostClient {
    addr: String,
    pool: Mutex<Vec<Conn>>,
    /// Framed connections for the image-carrying ops (export, import,
    /// replicate): raw bytes on the wire instead of a 2× hex blow-up,
    /// streamed in chunks with no whole-line materialization cap.
    frame_pool: Mutex<Vec<FrameStream>>,
    frame_bytes_out: AtomicU64,
    frame_bytes_in: AtomicU64,
    /// Dial timeout: a blackholed host (packets dropped, no RST) must
    /// not wedge a router thread for the OS SYN-retry window.
    connect_timeout: Duration,
    /// Per-read timeout so a silent peer cannot hang a router thread.
    /// Generous by default — a think with a big budget legitimately
    /// takes a while — and tunable via [`HostClient::with_read_timeout`].
    read_timeout: Duration,
}

impl HostClient {
    pub fn new(addr: impl Into<String>) -> HostClient {
        HostClient {
            addr: addr.into(),
            pool: Mutex::new(Vec::new()),
            frame_pool: Mutex::new(Vec::new()),
            frame_bytes_out: AtomicU64::new(0),
            frame_bytes_in: AtomicU64::new(0),
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(120),
        }
    }

    /// Raise (or lower) the reply timeout — deployments whose thinks run
    /// longer than the default 120 s should size this to their worst
    /// expected search, or a healthy host mid-think reads as unreachable.
    pub fn with_read_timeout(mut self, read_timeout: Duration) -> HostClient {
        self.read_timeout = read_timeout;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn dial(&self) -> std::io::Result<Conn> {
        use std::net::ToSocketAddrs;
        let addr = self
            .addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::other(format!("{} resolves to nothing", self.addr)))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        let writer = stream.try_clone()?;
        Ok(Conn { reader: BufReader::new(stream), writer })
    }

    fn dial_frame(&self) -> std::io::Result<FrameStream> {
        use std::net::ToSocketAddrs;
        let addr = self
            .addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::other(format!("{} resolves to nothing", self.addr)))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        Ok(FrameStream::new(stream))
    }

    /// Fold a framed connection's byte counters into the client-wide
    /// totals; `before` is its [`FrameStream::wire_bytes`] at checkout.
    fn settle_frame(&self, fs: &FrameStream, before: (u64, u64)) {
        let (out, inn) = fs.wire_bytes();
        self.frame_bytes_out.fetch_add(out - before.0, Ordering::Relaxed);
        self.frame_bytes_in.fetch_add(inn - before.1, Ordering::Relaxed);
    }

    /// Cumulative `(sent, received)` bytes on the wire across every
    /// binary-framed call this client has made — the measurement behind
    /// the "image travels at ~1× its size, not 2× hex" guarantee.
    pub fn frame_wire_bytes(&self) -> (u64, u64) {
        (self.frame_bytes_out.load(Ordering::Relaxed), self.frame_bytes_in.load(Ordering::Relaxed))
    }

    /// One request/reply line round trip on a connection, distinguishing
    /// *where* it failed — a failed write means the request never left
    /// this process (always safe to retry); a failed read means it may
    /// have been delivered and executed (only idempotent ops may retry).
    fn try_call(conn: &mut Conn, line: &str) -> Attempt {
        if conn.writer.write_all(line.as_bytes()).is_err()
            || conn.writer.write_all(b"\n").is_err()
            || conn.writer.flush().is_err()
        {
            return Attempt::WriteFailed;
        }
        let mut reply = String::new();
        match conn.reader.read_line(&mut reply) {
            Ok(0) | Err(_) => Attempt::ReadFailed, // EOF or timeout/reset
            Ok(_) => Attempt::Reply(reply),
        }
    }

    /// Send one raw request line and parse the reply document — for
    /// **idempotent** requests (ping, metrics, health, best): a lost
    /// reply is retried once on a fresh dial. A host that cannot be
    /// reached is the typed [`HostUnreachable`].
    pub fn call(&self, line: &str) -> Result<Json> {
        self.call_policy(line, true)
    }

    /// Like [`HostClient::call`] but for requests with **side effects**
    /// (open, think, advance, close, export, import, install): a stale
    /// pooled connection whose *write* fails is retried on a fresh dial
    /// (the request provably never left), but a lost *reply* is not —
    /// the op may have executed, and re-sending it would double-step an
    /// env, re-run a search, or collide an import. Callers see
    /// [`HostUnreachable`] and decide (the migration handshake aborts
    /// and unseals; clients surface the error).
    pub fn call_once(&self, line: &str) -> Result<Json> {
        self.call_policy(line, false)
    }

    fn call_policy(&self, line: &str, idempotent: bool) -> Result<Json> {
        for attempt in 0..2 {
            let conn = if attempt == 0 { self.pool.lock().unwrap().pop() } else { None };
            let mut conn = match conn {
                Some(c) => c,
                None => match self.dial() {
                    Ok(c) => c,
                    Err(_) => continue, // nothing sent; next attempt re-dials
                },
            };
            let reply = match Self::try_call(&mut conn, line) {
                Attempt::Reply(reply) => reply,
                Attempt::WriteFailed => continue,
                Attempt::ReadFailed if idempotent => continue,
                Attempt::ReadFailed => break, // may have landed: do not re-execute
            };
            let v = Json::parse(reply.trim()).with_context(|| {
                format!("host {} sent an unparseable reply", self.addr)
            })?;
            self.pool.lock().unwrap().push(conn);
            return Ok(v);
        }
        Err(anyhow::Error::new(HostUnreachable { host: self.addr.clone() }))
    }

    /// Split an `ok:false` reply back into the typed error the host
    /// raised; `session` contextualizes a rebuilt `Recovering`.
    fn expect_ok(&self, v: Json, session: u64) -> Result<Json> {
        if v.get("ok").and_then(|b| b.as_bool()) == Some(true) {
            return Ok(v);
        }
        let msg = v
            .get("error")
            .and_then(|e| e.as_str())
            .unwrap_or("unknown remote error")
            .to_string();
        if v.get("busy").and_then(|b| b.as_bool()) == Some(true) {
            return Err(anyhow::Error::new(Busy { open: 0, limit: 0 })
                .context(format!("host {}: {msg}", self.addr)));
        }
        if v.get("recovering").and_then(|b| b.as_bool()) == Some(true) {
            return Err(anyhow::Error::new(Recovering { session })
                .context(format!("host {}: {msg}", self.addr)));
        }
        if v.get("lease_lost").and_then(|b| b.as_bool()) == Some(true) {
            return Err(anyhow::Error::new(LeaseLost { session })
                .context(format!("host {}: {msg}", self.addr)));
        }
        if v.get("zero_think").and_then(|b| b.as_bool()) == Some(true) {
            return Err(anyhow::Error::new(ZeroThink { session })
                .context(format!("host {}: {msg}", self.addr)));
        }
        Err(anyhow!("host {}: {msg}", self.addr))
    }

    fn ok_call(&self, line: &str, session: u64) -> Result<Json> {
        let v = self.call(line)?;
        self.expect_ok(v, session)
    }

    fn ok_call_once(&self, line: &str, session: u64) -> Result<Json> {
        let v = self.call_once(line)?;
        self.expect_ok(v, session)
    }

    pub fn ping(&self) -> Result<()> {
        self.ok_call(r#"{"op":"ping"}"#, 0).map(|_| ())
    }

    /// Open under a router-assigned id. The env is reconstructed host-
    /// side as `make_env(env_name, opts.env_seed)` — the same durable
    /// convention recovery and migration already rely on — so only
    /// wire-expressible spec fields travel (`beta`/`expand_prob` stay at
    /// their family defaults, which the wire cannot change either).
    pub fn open_with_id(
        &self,
        id: u64,
        env_name: &str,
        spec: &SearchSpec,
        opts: &SessionOptions,
    ) -> Result<u64> {
        let mut fields = vec![
            ("op".to_string(), Json::Str("open".to_string())),
            ("env".to_string(), Json::Str(env_name.to_string())),
            ("id".to_string(), Json::Num(id as f64)),
            ("seed".to_string(), Json::Num(opts.env_seed as f64)),
            ("sims".to_string(), Json::Num(spec.max_simulations as f64)),
            ("rollout".to_string(), Json::Num(spec.rollout_limit as f64)),
            ("depth".to_string(), Json::Num(spec.max_depth as f64)),
            ("width".to_string(), Json::Num(spec.max_width as f64)),
            ("gamma".to_string(), Json::Num(spec.gamma)),
            ("weight".to_string(), Json::Num(opts.weight)),
        ];
        if let Some(budget) = opts.total_sim_budget {
            fields.push(("budget".to_string(), Json::Num(budget as f64)));
        }
        if opts.class != QosClass::Throughput {
            // Throughput is the wire default; omit it so older hosts
            // still parse the request.
            fields.push(("class".to_string(), Json::Str(opts.class.name().to_string())));
        }
        let v = self.ok_call_once(&Json::Obj(fields).render(), id)?;
        v.get("session")
            .and_then(|s| s.as_u64())
            .ok_or_else(|| anyhow!("host {}: open reply missing session id", self.addr))
    }

    pub fn think(&self, session: u64, sims: u32) -> Result<ThinkReply> {
        self.think_traced(session, sims, 0)
    }

    /// [`HostClient::think`] carrying a trace id the remote host stamps
    /// on every journal event of the think (0 = untraced, omitted from
    /// the wire so older hosts still parse the request).
    pub fn think_traced(&self, session: u64, sims: u32, trace: u64) -> Result<ThinkReply> {
        let line = if trace == 0 {
            format!(r#"{{"op":"think","session":{session},"sims":{sims}}}"#)
        } else {
            format!(r#"{{"op":"think","session":{session},"sims":{sims},"trace":{trace}}}"#)
        };
        self.think_call(&line, session)
    }

    /// Deadline-bounded think: `think_ms` caps the wall clock (measured
    /// on the owning shard), `sims` caps the budget (0 = the session
    /// default), and the reply's `cutoff` says which bound ended the
    /// search. Like every think, never retried on a lost reply.
    pub fn think_deadline(
        &self,
        session: u64,
        sims: u32,
        think_ms: u64,
        trace: u64,
    ) -> Result<ThinkReply> {
        let mut line =
            format!(r#"{{"op":"think","session":{session},"sims":{sims},"think_ms":{think_ms}"#);
        if trace != 0 {
            line.push_str(&format!(r#","trace":{trace}"#));
        }
        line.push('}');
        self.think_call(&line, session)
    }

    fn think_call(&self, line: &str, session: u64) -> Result<ThinkReply> {
        let v = self.ok_call_once(line, session)?;
        let field = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow!("host {}: think reply missing {key:?}", self.addr))
        };
        Ok(ThinkReply {
            action: field("action")? as usize,
            value: field("value")?,
            sims: field("sims")? as u32,
            tree_size: field("tree")? as usize,
            elapsed_ms: field("ms")?,
            quiescent: v.get("quiescent").and_then(|q| q.as_bool()).unwrap_or(false),
            remaining: v.get("remaining").and_then(|r| r.as_u64()),
            cutoff: v.get("cutoff").and_then(|c| c.as_bool()),
        })
    }

    pub fn advance(&self, session: u64, action: usize) -> Result<AdvanceReply> {
        let line = format!(r#"{{"op":"advance","session":{session},"action":{action}}}"#);
        let v = self.ok_call_once(&line, session)?;
        let flag = |key: &str| v.get(key).and_then(|x| x.as_bool()).unwrap_or(false);
        Ok(AdvanceReply {
            reward: v.get("reward").and_then(|r| r.as_f64()).unwrap_or(0.0),
            done: flag("done"),
            reused: flag("reused"),
            retained: v.get("retained").and_then(|r| r.as_u64()).unwrap_or(0) as usize,
            steps: v.get("steps").and_then(|s| s.as_u64()).unwrap_or(0),
        })
    }

    pub fn best_action(&self, session: u64) -> Result<usize> {
        let v = self.ok_call(&format!(r#"{{"op":"best","session":{session}}}"#), session)?;
        v.get("action")
            .and_then(|a| a.as_usize())
            .ok_or_else(|| anyhow!("host {}: best reply missing action", self.addr))
    }

    pub fn close(&self, session: u64) -> Result<CloseReply> {
        let v = self.ok_call_once(&format!(r#"{{"op":"close","session":{session}}}"#), session)?;
        let int = |key: &str| v.get(key).and_then(|x| x.as_u64()).unwrap_or(0);
        Ok(CloseReply {
            thinks: int("thinks"),
            sims: int("sims"),
            steps: int("steps"),
            unobserved: int("unobserved"),
        })
    }

    /// Migration source half: serialize + seal on the host, and stream
    /// the binary image back over a length-prefixed framed connection —
    /// raw bytes in chunks, so a big tree never pays the 2× hex blow-up
    /// or the line protocol's whole-image materialization cap. Retry
    /// policy mirrors [`HostClient::call_once`]: a failed *write* never
    /// left this process and re-dials; a lost *reply* may have sealed
    /// the session and must not re-execute.
    pub fn export(&self, session: u64) -> Result<Vec<u8>> {
        let line = format!(r#"{{"op":"export","session":{session}}}"#);
        for attempt in 0..2 {
            let fs = if attempt == 0 { self.frame_pool.lock().unwrap().pop() } else { None };
            let mut fs = match fs {
                Some(f) => f,
                None => match self.dial_frame() {
                    Ok(f) => f,
                    Err(_) => continue,
                },
            };
            let before = fs.wire_bytes();
            if fs.send(OP_REQ, line.as_bytes()).is_err() {
                self.settle_frame(&fs, before);
                continue; // request never left: safe to re-dial
            }
            let got = fs.recv_blob();
            self.settle_frame(&fs, before);
            let got = match got {
                Ok(g) => g,
                Err(_) => break, // may have sealed remotely: do not re-execute
            };
            match got {
                BlobOrReply::Blob { header, bytes } => {
                    let v = Json::parse(header.trim()).with_context(|| {
                        format!("host {} sent an unparseable blob header", self.addr)
                    })?;
                    self.frame_pool.lock().unwrap().push(fs);
                    self.expect_ok(v, session)?;
                    return Ok(bytes);
                }
                BlobOrReply::Line(reply) => {
                    // Plain reply instead of a blob: the host refused
                    // (unknown session, sealed, …) — surface it typed.
                    let v = Json::parse(reply.trim()).with_context(|| {
                        format!("host {} sent an unparseable reply", self.addr)
                    })?;
                    self.frame_pool.lock().unwrap().push(fs);
                    self.expect_ok(v, session)?;
                    anyhow::bail!("host {}: export reply carried no image", self.addr);
                }
            }
        }
        Err(anyhow::Error::new(HostUnreachable { host: self.addr.clone() }))
    }

    /// Migration target half: install an image (durable `Open` lands
    /// before the host acks). The image streams as raw framed chunks;
    /// the host only executes once the complete, length-checked blob
    /// has assembled, so a write failure mid-stream provably did not
    /// import and re-dials, while a lost reply does not retry.
    pub fn import(&self, image: &[u8]) -> Result<u64> {
        let header = format!(r#"{{"op":"import","len":{}}}"#, image.len());
        for attempt in 0..2 {
            let fs = if attempt == 0 { self.frame_pool.lock().unwrap().pop() } else { None };
            let mut fs = match fs {
                Some(f) => f,
                None => match self.dial_frame() {
                    Ok(f) => f,
                    Err(_) => continue,
                },
            };
            let before = fs.wire_bytes();
            if fs.send_blob(&header, image).is_err() {
                self.settle_frame(&fs, before);
                continue; // partial blob never dispatches: safe to re-dial
            }
            let reply = fs.recv_reply();
            self.settle_frame(&fs, before);
            let reply = match reply {
                Ok(r) => r,
                Err(_) => break, // may have imported: do not re-execute
            };
            let v = Json::parse(reply.trim())
                .with_context(|| format!("host {} sent an unparseable reply", self.addr))?;
            self.frame_pool.lock().unwrap().push(fs);
            let v = self.expect_ok(v, 0)?;
            return v
                .get("session")
                .and_then(|s| s.as_u64())
                .ok_or_else(|| anyhow!("host {}: import reply missing session id", self.addr));
        }
        Err(anyhow::Error::new(HostUnreachable { host: self.addr.clone() }))
    }

    /// Resolve a seal: `landed = true` forgets the host's copy,
    /// `landed = false` unseals it.
    pub fn install(&self, session: u64, landed: bool) -> Result<()> {
        let line = format!(r#"{{"op":"install","session":{session},"landed":{landed}}}"#);
        self.ok_call_once(&line, session).map(|_| ())
    }

    pub fn metrics(&self) -> Result<ServiceMetrics> {
        let v = self.ok_call(r#"{"op":"metrics"}"#, 0)?;
        Ok(metrics_from_json(&v))
    }

    /// Read the remote event journal (idempotent, so a lost reply
    /// retries). `session = None` tails the whole host.
    pub fn trace(&self, session: Option<u64>, limit: usize) -> Result<Vec<Event>> {
        let line = match session {
            Some(sid) => format!(r#"{{"op":"trace","session":{sid},"limit":{limit}}}"#),
            None => format!(r#"{{"op":"trace","limit":{limit}}}"#),
        };
        let v = self.ok_call(&line, session.unwrap_or(0))?;
        let Some(Json::Arr(raw)) = v.get("events") else {
            anyhow::bail!("host {}: trace reply missing events", self.addr);
        };
        raw.iter()
            .map(event_from_json)
            .collect::<Result<Vec<Event>>>()
            .with_context(|| format!("host {} sent a malformed trace event", self.addr))
    }

    /// Read a remote session's search-health summary (idempotent, so a
    /// lost reply retries) — the wire `inspect` op, computed on the
    /// owning shard without exporting the image.
    pub fn inspect(&self, session: u64, topk: usize) -> Result<crate::obs::SearchSummary> {
        let line = format!(r#"{{"op":"inspect","session":{session},"topk":{topk}}}"#);
        let v = self.ok_call(&line, session)?;
        summary_from_json(&v)
            .with_context(|| format!("host {} sent a malformed inspect reply", self.addr))
    }

    /// Announce a shard host to a router (idempotent; safe to retry).
    /// Returns the membership epoch the router granted.
    pub fn join(&self, addr: &str, standby: Option<&str>) -> Result<u64> {
        let mut fields = vec![
            ("op".to_string(), Json::Str("join".to_string())),
            ("addr".to_string(), Json::Str(addr.to_string())),
        ];
        if let Some(s) = standby {
            fields.push(("standby".to_string(), Json::Str(s.to_string())));
        }
        let v = self.ok_call(&Json::Obj(fields).render(), 0)?;
        v.get("epoch")
            .and_then(|e| e.as_u64())
            .ok_or_else(|| anyhow!("host {}: join reply missing epoch", self.addr))
    }

    /// Heartbeat a shard host's liveness to a router. `Ok(false)` means
    /// the router does not know the host — it should re-[`HostClient::join`].
    pub fn heartbeat(&self, addr: &str) -> Result<bool> {
        let line = Json::Obj(vec![
            ("op".to_string(), Json::Str("heartbeat".to_string())),
            ("addr".to_string(), Json::Str(addr.to_string())),
        ])
        .render();
        let v = self.ok_call(&line, 0)?;
        Ok(v.get("known").and_then(|k| k.as_bool()).unwrap_or(false))
    }

    /// Ask a router to drain a host: migrate its sessions out, then
    /// forget it. Returns how many sessions moved. Not retried on a lost
    /// reply — the drain may have completed, and re-draining a forgotten
    /// host is an error, not a no-op.
    pub fn drain(&self, addr: &str) -> Result<usize> {
        let line = Json::Obj(vec![
            ("op".to_string(), Json::Str("drain".to_string())),
            ("addr".to_string(), Json::Str(addr.to_string())),
        ])
        .render();
        let v = self.ok_call_once(&line, 0)?;
        v.get("moved")
            .and_then(|m| m.as_usize())
            .ok_or_else(|| anyhow!("host {}: drain reply missing moved", self.addr))
    }

    /// Ship one replication frame to a standby host as a raw framed
    /// blob (no hex doubling on the shipping path). Idempotent by
    /// construction — the standby skips already-applied sequences — so a
    /// lost reply retries on a fresh dial, exactly like
    /// [`HostClient::call`]. Returns the standby's contiguous ack.
    pub fn replicate(&self, shard: usize, frame: &[u8]) -> Result<u64> {
        let header = format!(r#"{{"op":"replicate","shard":{shard},"len":{}}}"#, frame.len());
        for attempt in 0..2 {
            let fs = if attempt == 0 { self.frame_pool.lock().unwrap().pop() } else { None };
            let mut fs = match fs {
                Some(f) => f,
                None => match self.dial_frame() {
                    Ok(f) => f,
                    Err(_) => continue,
                },
            };
            let before = fs.wire_bytes();
            if fs.send_blob(&header, frame).is_err() {
                self.settle_frame(&fs, before);
                continue;
            }
            let reply = fs.recv_reply();
            self.settle_frame(&fs, before);
            let reply = match reply {
                Ok(r) => r,
                Err(_) => continue, // idempotent: a lost ack retries
            };
            let v = Json::parse(reply.trim())
                .with_context(|| format!("host {} sent an unparseable reply", self.addr))?;
            self.frame_pool.lock().unwrap().push(fs);
            let v = self.expect_ok(v, 0)?;
            return v
                .get("acked")
                .and_then(|a| a.as_u64())
                .ok_or_else(|| anyhow!("host {}: replicate reply missing acked", self.addr));
        }
        Err(anyhow::Error::new(HostUnreachable { host: self.addr.clone() }))
    }

    /// Read a standby host's per-shard replication progress (idempotent)
    /// — the resume handshake for a reconnecting primary.
    pub fn repl_status(&self) -> Result<Vec<ReplShardStatus>> {
        let v = self.ok_call(r#"{"op":"repl_status"}"#, 0)?;
        let Some(Json::Arr(raw)) = v.get("shards") else {
            anyhow::bail!("host {}: repl_status reply missing shards", self.addr);
        };
        let mut out = Vec::with_capacity(raw.len());
        for item in raw {
            let int = |key: &str| {
                item.get(key)
                    .and_then(|x| x.as_u64())
                    .ok_or_else(|| anyhow!("host {}: repl_status entry missing {key:?}", self.addr))
            };
            out.push(ReplShardStatus {
                shard: int("shard")? as usize,
                start: int("start")?,
                acked: int("acked")?,
            });
        }
        Ok(out)
    }

    /// Tell a standby host its primary is gone: fold the replicated
    /// streams into live sessions. Idempotent (a second promote replays
    /// nothing new), so a lost reply retries.
    pub fn promote(&self) -> Result<PromoteReply> {
        let v = self.ok_call(r#"{"op":"promote"}"#, 0)?;
        let int = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow!("host {}: promote reply missing {key:?}", self.addr))
        };
        Ok(PromoteReply { sessions: int("sessions")? as usize, steps: int("steps")? })
    }

    pub fn health(&self) -> Result<RemoteHealth> {
        let v = self.ok_call(r#"{"op":"health"}"#, 0)?;
        let mut sessions = Vec::new();
        if let Some(Json::Arr(items)) = v.get("sessions") {
            for item in items {
                let int = |key: &str| item.get(key).and_then(|x| x.as_u64());
                let Some(id) = int("id") else { continue };
                sessions.push(SessionStat {
                    id,
                    thinks: int("thinks").unwrap_or(0),
                    steps: int("steps").unwrap_or(0),
                    sealed: item.get("sealed").and_then(|s| s.as_bool()).unwrap_or(false),
                });
            }
        }
        Ok(RemoteHealth {
            role: v
                .get("role")
                .and_then(|r| r.as_str())
                .unwrap_or("unknown")
                .to_string(),
            shards: v.get("shards").and_then(|s| s.as_usize()).unwrap_or(0),
            sessions_open: v.get("sessions_open").and_then(|s| s.as_usize()).unwrap_or(0),
            sessions,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::scheduler::{SearchService, ServiceConfig};
    use crate::service::server::TcpServer;

    fn spec(seed: u64) -> SearchSpec {
        SearchSpec {
            max_simulations: 12,
            rollout_limit: 8,
            max_depth: 10,
            seed,
            ..SearchSpec::default()
        }
    }

    fn start_host() -> (SearchService, TcpServer, HostClient) {
        let svc = SearchService::start(ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..Default::default()
        });
        let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        let client = HostClient::new(server.local_addr().to_string());
        (svc, server, client)
    }

    #[test]
    fn full_lifecycle_through_the_client() {
        let (_svc, _server, client) = start_host();
        client.ping().unwrap();
        let opts = SessionOptions { env_seed: 5, ..SessionOptions::default() };
        let sid = client.open_with_id(41, "garnet", &spec(5), &opts).unwrap();
        assert_eq!(sid, 41);
        let t = client.think(sid, 0).unwrap();
        assert_eq!(t.sims, 12);
        assert!(t.quiescent);
        let a = client.advance(sid, t.action).unwrap();
        assert_eq!(a.steps, 1);
        let best = client.best_action(sid).unwrap();
        assert!(best < 3, "garnet's wire construction has 3 actions");
        let h = client.health().unwrap();
        assert_eq!(h.role, "service");
        assert_eq!(h.sessions.len(), 1);
        assert_eq!(h.sessions[0].id, 41);
        let c = client.close(sid).unwrap();
        assert_eq!(c.unobserved, 0);
        let m = client.metrics().unwrap();
        assert_eq!(m.sessions_closed, 1);
    }

    #[test]
    fn export_import_moves_a_session_between_processes_in_miniature() {
        let (_sa, _serva, a) = start_host();
        let (_sb, _servb, b) = start_host();
        let opts = SessionOptions { env_seed: 9, ..SessionOptions::default() };
        let sid = a.open_with_id(7, "garnet", &spec(9), &opts).unwrap();
        a.think(sid, 8).unwrap();
        let best = a.best_action(sid).unwrap();
        let image = a.export(sid).unwrap();
        // Sealed: the source copy refuses ops with the recovering marker.
        let err = a.think(sid, 4).unwrap_err();
        assert!(err.downcast_ref::<Recovering>().is_some(), "got: {err:#}");
        let moved = b.import(&image).unwrap();
        assert_eq!(moved, sid);
        assert_eq!(b.best_action(sid).unwrap(), best, "tree moved bit-for-bit");
        a.install(sid, true).unwrap();
        assert!(a.best_action(sid).is_err(), "source forgot the session");
        let t = b.think(sid, 8).unwrap();
        assert!(t.quiescent);
        b.close(sid).unwrap();
    }

    #[test]
    fn refused_resolution_unseals_the_source() {
        let (_svc, _server, client) = start_host();
        let opts = SessionOptions { env_seed: 3, ..SessionOptions::default() };
        let sid = client.open_with_id(3, "garnet", &spec(3), &opts).unwrap();
        let _ = client.export(sid).unwrap();
        client.install(sid, false).unwrap();
        let t = client.think(sid, 6).unwrap();
        assert!(t.quiescent, "unsealed session must serve again");
        // Unsealing an unsealed session is a no-op, not an error.
        client.install(sid, false).unwrap();
        client.close(sid).unwrap();
    }

    #[test]
    fn traced_think_timeline_survives_the_wire() {
        use crate::obs::EventKind;
        let (_svc, _server, client) = start_host();
        let opts = SessionOptions { env_seed: 2, ..SessionOptions::default() };
        let sid = client.open_with_id(11, "garnet", &spec(2), &opts).unwrap();
        client.think_traced(sid, 8, 0xFEED).unwrap();
        let events = client.trace(Some(sid), 512).unwrap();
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.session == sid));
        let admit = events.iter().find(|e| e.kind == EventKind::Admit).unwrap();
        assert_eq!(admit.trace, 0xFEED);
        assert!(events.iter().any(|e| e.kind == EventKind::ReplySent));
        // `inspect` travels the same wire: a quiescent post-think summary.
        let s = client.inspect(sid, 3).unwrap();
        assert_eq!(s.session, sid);
        assert_eq!(s.unobserved, 0, "ΣO drains before the think reply");
        assert!(s.tree_size > 1);
        assert!(s.top.len() <= 3);
        client.close(sid).unwrap();
    }

    #[test]
    fn deadline_think_and_qos_class_travel_the_wire() {
        let (_svc, _server, client) = start_host();
        let opts = SessionOptions {
            env_seed: 6,
            class: QosClass::Latency,
            ..SessionOptions::default()
        };
        let sid = client.open_with_id(21, "garnet", &spec(6), &opts).unwrap();

        // Generous deadline: the sims cap drains first.
        let t = client.think_deadline(sid, 6, 60_000, 0).unwrap();
        assert_eq!(t.cutoff, Some(false));
        assert_eq!(t.sims, 6);

        // Tight deadline under a huge budget: the clock cuts, and the
        // folded tree is still quiescent at the reply.
        let t = client.think_deadline(sid, 1_000_000, 25, 0).unwrap();
        assert_eq!(t.cutoff, Some(true), "clock must cut a 1M-sim budget");
        assert!(t.quiescent);
        assert!(t.sims < 1_000_000);

        // Plain thinks carry no cutoff marker.
        let t = client.think(sid, 4).unwrap();
        assert_eq!(t.cutoff, None);

        // A 0/0 think maps back to the typed rejection, like Busy does.
        let zero = SearchSpec { max_simulations: 0, ..spec(6) };
        let sid2 = client.open_with_id(22, "garnet", &zero, &opts).unwrap();
        let err = client.think(sid2, 0).unwrap_err();
        assert!(err.downcast_ref::<ZeroThink>().is_some(), "expected ZeroThink, got: {err:#}");
        client.close(sid2).unwrap();
        client.close(sid).unwrap();
    }

    #[test]
    fn dead_host_is_a_typed_unreachable_error() {
        let (svc, server, client) = start_host();
        client.ping().unwrap();
        drop(server);
        drop(svc);
        let err = client.ping().unwrap_err();
        assert!(
            err.downcast_ref::<HostUnreachable>().is_some(),
            "expected HostUnreachable, got: {err:#}"
        );
    }

    #[test]
    fn remote_busy_maps_back_to_the_typed_error() {
        use crate::service::shard::{ShardedConfig, ShardedService};
        let svc = ShardedService::start(ShardedConfig {
            shards: 1,
            shard: ServiceConfig {
                expansion_workers: 1,
                simulation_workers: 1,
                ..ServiceConfig::default()
            },
            max_sessions_per_shard: Some(1),
            ..ShardedConfig::default()
        });
        let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
        let client = HostClient::new(server.local_addr().to_string());
        let opts = SessionOptions::default();
        client.open_with_id(1, "garnet", &spec(1), &opts).unwrap();
        let err = client.open_with_id(2, "garnet", &spec(2), &opts).unwrap_err();
        assert!(err.downcast_ref::<Busy>().is_some(), "expected Busy, got: {err:#}");
    }
}
