//! Simulation-step machinery: rollout policies and the bounded-rollout +
//! value-bootstrap return estimator (Appendix D).

pub mod policy;
pub mod simulate;

pub use policy::{GreedyPolicy, HeuristicPolicy, PolicyFactory, RandomPolicy, RolloutPolicy};
pub use simulate::simulation_return;
