//! Rollout (default) policies for the simulation step.
//!
//! The paper's simulation workers roll out a distilled PPO network
//! (Appendix D). Here the same role is filled by one of:
//!
//! * [`RandomPolicy`] — uniform over legal actions (the classic MCTS
//!   default, used as an ablation floor);
//! * [`HeuristicPolicy`] — softmax over the env's one-step heuristic
//!   (the teacher the network distills from);
//! * `NetworkPolicy` (in [`crate::runtime::policy`]) — the AOT-compiled
//!   policy-value network served through the PJRT inference server.
//!
//! Each simulation worker owns its own boxed policy, produced by a
//! [`PolicyFactory`] so every worker gets an independent rng stream.

use std::sync::Arc;

use crate::env::Env;
use crate::util::rng::Pcg32;

/// A default policy + value bootstrap used during simulation.
pub trait RolloutPolicy: Send {
    /// Pick an action among `env.legal_actions()` (must be non-empty).
    fn choose(&mut self, env: &dyn Env) -> usize;

    /// Bootstrap estimate of V(s) for truncated rollouts.
    fn value(&mut self, env: &dyn Env) -> f64;

    /// Human-readable name for experiment tables.
    fn name(&self) -> &'static str;
}

/// Constructor handed to worker pools; the argument seeds the worker's rng.
pub type PolicyFactory = Arc<dyn Fn(u64) -> Box<dyn RolloutPolicy> + Send + Sync>;

/// Uniform-random rollout policy.
pub struct RandomPolicy {
    rng: Pcg32,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed) }
    }

    pub fn factory() -> PolicyFactory {
        Arc::new(|seed| Box::new(RandomPolicy::new(seed)))
    }
}

impl RolloutPolicy for RandomPolicy {
    fn choose(&mut self, env: &dyn Env) -> usize {
        let legal = env.legal_actions();
        assert!(!legal.is_empty(), "choose() on state with no legal actions");
        *self.rng.choose(&legal)
    }

    fn value(&mut self, _env: &dyn Env) -> f64 {
        0.0
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Softmax-over-heuristic rollout policy — the build-time teacher
/// (python/compile/model.py `teacher_logits_value`) evaluated directly.
pub struct HeuristicPolicy {
    rng: Pcg32,
    /// Softmax sharpness; matches the teacher's TEACHER_SCALE.
    pub scale: f64,
}

impl HeuristicPolicy {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed), scale: 4.0 }
    }

    pub fn factory() -> PolicyFactory {
        Arc::new(|seed| Box::new(HeuristicPolicy::new(seed)))
    }
}

impl RolloutPolicy for HeuristicPolicy {
    fn choose(&mut self, env: &dyn Env) -> usize {
        let legal = env.legal_actions();
        assert!(!legal.is_empty(), "choose() on state with no legal actions");
        // Softmax over scaled heuristics (numerically-stable exp).
        let logits: Vec<f64> = legal
            .iter()
            .map(|&a| self.scale * env.action_heuristic(a))
            .collect();
        let max = logits.iter().cloned().fold(f64::MIN, f64::max);
        let weights: Vec<f64> = logits.iter().map(|l| (l - max).exp()).collect();
        legal[self.rng.weighted(&weights)]
    }

    fn value(&mut self, env: &dyn Env) -> f64 {
        env.heuristic_value()
    }

    fn name(&self) -> &'static str {
        "heuristic"
    }
}

/// Greedy argmax over the heuristic (used by the pass-rate system's
/// synthetic skilled players; noise is injected by its caller).
pub struct GreedyPolicy;

impl GreedyPolicy {
    pub fn factory() -> PolicyFactory {
        Arc::new(|_| Box::new(GreedyPolicy))
    }
}

impl RolloutPolicy for GreedyPolicy {
    fn choose(&mut self, env: &dyn Env) -> usize {
        let legal = env.legal_actions();
        assert!(!legal.is_empty());
        legal
            .into_iter()
            .max_by(|&a, &b| {
                env.action_heuristic(a)
                    .partial_cmp(&env.action_heuristic(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap()
    }

    fn value(&mut self, env: &dyn Env) -> f64 {
        env.heuristic_value()
    }

    fn name(&self) -> &'static str {
        "greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;

    fn env() -> Garnet {
        Garnet::new(10, 4, 30, 0.0, 5)
    }

    #[test]
    fn random_policy_only_picks_legal() {
        let e = env();
        let mut p = RandomPolicy::new(1);
        for _ in 0..100 {
            let a = p.choose(&e);
            assert!(e.legal_actions().contains(&a));
        }
        assert_eq!(p.value(&e), 0.0);
    }

    #[test]
    fn heuristic_policy_prefers_high_reward_actions() {
        let e = env();
        let mut p = HeuristicPolicy::new(2);
        let best = (0..4)
            .max_by(|&a, &b| {
                e.action_heuristic(a)
                    .partial_cmp(&e.action_heuristic(b))
                    .unwrap()
            })
            .unwrap();
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[p.choose(&e)] += 1;
        }
        let argmax = counts.iter().enumerate().max_by_key(|&(_, c)| c).unwrap().0;
        assert_eq!(argmax, best, "softmax mode should match heuristic argmax");
    }

    #[test]
    fn greedy_policy_is_deterministic_argmax() {
        let e = env();
        let mut p = GreedyPolicy;
        let a1 = p.choose(&e);
        let a2 = p.choose(&e);
        assert_eq!(a1, a2);
        for other in 0..4 {
            assert!(e.action_heuristic(other) <= e.action_heuristic(a1) + 1e-12);
        }
    }

    #[test]
    fn factories_produce_independent_streams() {
        let f = HeuristicPolicy::factory();
        let e = env();
        let mut p1 = f(1);
        let mut p2 = f(2);
        // Distinct seeds should (almost surely) diverge over many draws.
        let same = (0..200)
            .filter(|_| p1.choose(&e) == p2.choose(&e))
            .count();
        assert!(same < 200, "streams should not be identical");
    }

    #[test]
    fn heuristic_value_passthrough() {
        let e = env();
        let mut p = HeuristicPolicy::new(3);
        assert_eq!(p.value(&e), e.heuristic_value());
    }
}
