//! The simulation step: bounded rollout + value bootstrap.
//!
//! Implements Appendix D's estimator exactly:
//!
//! ```text
//! R_simu = Σ_{i<L} γ^i r_i + γ^L V(s_L)        (bounded rollout, L = limit)
//! R      = 0.5 · R_simu + 0.5 · V(s_0)         (variance reduction)
//! ```
//!
//! where V is the rollout policy's value head. Terminal states inside the
//! rollout stop the sum early with no bootstrap.

use crate::env::Env;
use crate::eval::policy::RolloutPolicy;

/// Run one simulation from the (already-positioned) `env`, consuming it.
///
/// The environment is mutated freely — callers hand in a worker-local
/// clone restored from the game-state buffer.
pub fn simulation_return(
    env: &mut dyn Env,
    policy: &mut dyn RolloutPolicy,
    gamma: f64,
    limit: u32,
) -> f64 {
    if env.is_terminal() {
        return 0.0;
    }
    let v0 = policy.value(env);
    let mut total = 0.0;
    let mut disc = 1.0;
    let mut terminated = false;
    for _ in 0..limit {
        let action = policy.choose(env);
        let step = env.step(action);
        total += disc * step.reward;
        disc *= gamma;
        if step.done {
            terminated = true;
            break;
        }
    }
    if !terminated {
        total += disc * policy.value(env);
    }
    0.5 * total + 0.5 * v0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;
    use crate::eval::policy::{GreedyPolicy, HeuristicPolicy, RandomPolicy};

    #[test]
    fn terminal_state_returns_zero() {
        let mut e = Garnet::new(6, 2, 1, 0.0, 1);
        e.step(0); // horizon 1: now terminal
        assert!(e.is_terminal());
        let mut p = RandomPolicy::new(0);
        assert_eq!(simulation_return(&mut e, &mut p, 0.99, 100), 0.0);
    }

    #[test]
    fn returns_are_finite_and_reproducible() {
        let run = |seed| {
            let mut e = Garnet::new(12, 3, 50, 0.0, 9);
            let mut p = RandomPolicy::new(seed);
            simulation_return(&mut e, &mut p, 0.99, 100)
        };
        assert_eq!(run(4), run(4));
        assert!(run(4).is_finite());
    }

    #[test]
    fn greedy_rollouts_bounded_by_optimal() {
        // Garnet rewards are in [0,1]; check the estimator against the
        // exact optimum (plus the 0.5·V(s0) blend slack, |V| ≤ 1).
        let e0 = Garnet::new(10, 3, 6, 0.0, 7);
        let opt = e0.optimal_value(0, 6);
        let mut e = e0.clone();
        let mut p = GreedyPolicy;
        let r = simulation_return(&mut e, &mut p, 1.0, 6);
        assert!(r <= 0.5 * opt + 0.5 + 1e-9, "r {r} vs opt {opt}");
    }

    #[test]
    fn limit_truncates_rollout() {
        // limit=0: no steps, return must be 0.5·V + 0.5·V = V(s0).
        let mut e = Garnet::new(8, 2, 100, 0.0, 3);
        let mut p = HeuristicPolicy::new(1);
        let v0 = e.heuristic_value();
        let r = simulation_return(&mut e, &mut p, 0.99, 0);
        assert!((r - v0).abs() < 1e-12);
    }

    #[test]
    fn gamma_zero_only_counts_first_reward_and_v0() {
        let e0 = Garnet::new(8, 2, 100, 0.0, 11);
        let mut e = e0.clone();
        let mut p = GreedyPolicy;
        let a = p.choose(&e0);
        let first_reward = e0.action_heuristic(a); // garnet heuristic == immediate reward
        let v0 = e0.heuristic_value();
        let r = simulation_return(&mut e, &mut p, 0.0, 100);
        // gamma=0: R_simu = r_0 + 0·V; R = 0.5 r_0 + 0.5 v0
        assert!((r - (0.5 * first_reward + 0.5 * v0)).abs() < 1e-9);
    }
}
