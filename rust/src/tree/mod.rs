//! Search-tree substrate: arena storage, node statistics {V, N, O},
//! and the UCT / WU-UCT / virtual-loss tree policies.

pub mod arena;
pub mod node;
pub mod policy;

pub use arena::Tree;
pub use node::{Node, NodeId};
pub use policy::{score_child, select_child, select_child_scalar, ucb_score, ScoreMode};
