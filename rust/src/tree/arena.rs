//! Index-arena search tree.
//!
//! Nodes live in a flat `Vec` and refer to each other by [`NodeId`]; this
//! keeps the selection hot loop allocation-free and cache-friendly (see
//! DESIGN.md §Perf) and sidesteps ownership cycles entirely.
//!
//! Selection additionally reads through a lazily-maintained
//! structure-of-arrays mirror ([`ChildLanes`]): per node, its children's
//! scoring inputs packed into contiguous lanes so the argmax scan in
//! `tree::policy` runs over flat `f64`/`u32` slices the compiler can
//! vectorize, instead of pointer-chasing one `Node` per child.

use std::sync::{Mutex, PoisonError};

use crate::tree::node::{Node, NodeId};

/// One node's children, scoring inputs split into parallel lanes.
/// `ids[k]` is the child whose statistics sit at index `k` of every other
/// lane; lane order is the node's `children` order, so a lowest-index
/// tie-break over lanes equals one over `children`.
#[derive(Debug, Default)]
pub(crate) struct ChildLanes {
    pub(crate) ids: Vec<NodeId>,
    pub(crate) n: Vec<u32>,
    pub(crate) o: Vec<u32>,
    pub(crate) v: Vec<f64>,
    pub(crate) vloss: Vec<f64>,
    pub(crate) vcount: Vec<u32>,
}

/// Lazy SoA mirror of the whole tree: one [`ChildLanes`] row per node,
/// rebuilt on first read after any mutation touching that row. Rows are
/// invalidated conservatively (a mutable borrow of a node dirties the
/// node's row and its parent's) and never eagerly rebuilt, so trees that
/// never select — store decode, replication standbys — pay nothing.
#[derive(Debug, Default)]
struct SoaMirror {
    rows: Vec<ChildLanes>,
    /// `ok[i]` ⇔ `rows[i]` matches the live nodes.
    ok: Vec<bool>,
}

/// The search tree. Root is always node 0.
#[derive(Debug)]
pub struct Tree {
    nodes: Vec<Node>,
    /// Depth of the deepest node, maintained on insert/re-root so
    /// [`Tree::max_depth`] is O(1) — the `inspect` op reads it per tick.
    deepest: u32,
    /// Interior-mutable so refresh works through `&Tree` (selection takes
    /// the tree immutably); a `Mutex` rather than `RefCell` keeps `Tree:
    /// Sync`. Uncontended in practice — the search master owns the tree.
    soa: Mutex<SoaMirror>,
}

impl Clone for Tree {
    fn clone(&self) -> Tree {
        // The mirror is a cache: clones start cold and rebuild on demand.
        Tree { nodes: self.nodes.clone(), deepest: self.deepest, soa: Mutex::default() }
    }
}

impl Tree {
    /// New tree containing only a root node.
    pub fn new() -> Tree {
        Tree { nodes: vec![Node::new(None, 0, 0)], deepest: 0, soa: Mutex::default() }
    }

    /// Dirty one mirror row. Rows beyond the mirror's current size are
    /// implicitly dirty (they materialize stale when the mirror grows).
    fn soa_touch(&self, id: NodeId) {
        let mut m = self.soa.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(ok) = m.ok.get_mut(id) {
            *ok = false;
        }
    }

    /// Drop the whole mirror — for structural rewrites (re-rooting).
    fn soa_reset(&self) {
        let mut m = self.soa.lock().unwrap_or_else(PoisonError::into_inner);
        m.rows.clear();
        m.ok.clear();
    }

    /// Run `f` over `parent`'s child lanes, refreshing the row first if
    /// it is stale. `f` must not re-enter the tree's SoA accessors (the
    /// mirror lock is held for the duration).
    pub(crate) fn with_child_lanes<R>(&self, parent: NodeId, f: impl FnOnce(&ChildLanes) -> R) -> R {
        let mut m = self.soa.lock().unwrap_or_else(PoisonError::into_inner);
        if m.rows.len() != self.nodes.len() {
            // Grow lazily; new rows start stale. Shrinks only happen via
            // `advance_root`, which resets the mirror outright.
            m.rows.resize_with(self.nodes.len(), ChildLanes::default);
            m.ok.resize(self.nodes.len(), false);
        }
        if !m.ok[parent] {
            let node = &self.nodes[parent];
            let row = &mut m.rows[parent];
            row.ids.clear();
            row.n.clear();
            row.o.clear();
            row.v.clear();
            row.vloss.clear();
            row.vcount.clear();
            for &(_, c) in &node.children {
                let ch = &self.nodes[c];
                row.ids.push(c);
                row.n.push(ch.n);
                row.o.push(ch.o);
                row.v.push(ch.v);
                row.vloss.push(ch.vloss);
                row.vcount.push(ch.vcount);
            }
            m.ok[parent] = true;
        }
        f(&m.rows[parent])
    }

    pub const ROOT: NodeId = 0;

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        false // a tree always has its root
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        // A mutable borrow may change this node's statistics (they live in
        // the parent's lanes) or its child list (its own lanes).
        self.soa_touch(id);
        if let Some(p) = self.nodes[id].parent {
            self.soa_touch(p);
        }
        &mut self.nodes[id]
    }

    /// Add a child of `parent` reached via `action`; returns the new id.
    /// Panics if `action` is already expanded under `parent`.
    pub fn add_child(&mut self, parent: NodeId, action: usize) -> NodeId {
        assert!(
            self.nodes[parent].child_for(action).is_none(),
            "action {action} already expanded under node {parent}"
        );
        let depth = self.nodes[parent].depth + 1;
        let id = self.nodes.len();
        self.soa_touch(parent); // child list grows; the new node's own row
                                // materializes stale when the mirror grows
        self.nodes.push(Node::new(Some(parent), action, depth));
        self.nodes[parent].children.push((action, id));
        self.deepest = self.deepest.max(depth);
        id
    }

    /// Path from `id` up to and including the root, starting at `id`.
    pub fn path_to_root(&self, id: NodeId) -> Vec<NodeId> {
        let mut path = vec![id];
        let mut cur = id;
        while let Some(p) = self.nodes[cur].parent {
            path.push(p);
            cur = p;
        }
        path
    }

    /// Walk ancestors (including `id`) applying `f`.
    pub fn for_path_to_root(&mut self, id: NodeId, mut f: impl FnMut(&mut Node)) {
        let mut cur = Some(id);
        while let Some(c) = cur {
            // Dirtying every visited row covers all affected lanes: each
            // visited node's parent is the next node visited, so the row
            // holding a changed node's statistics is always dirtied too.
            self.soa_touch(c);
            f(&mut self.nodes[c]);
            cur = self.nodes[c].parent;
        }
    }

    /// Child of the root with the highest observed visit count (ties
    /// broken by value) — the action the search recommends.
    pub fn best_root_action(&self) -> Option<usize> {
        self.nodes[Self::ROOT]
            .children
            .iter()
            .max_by(|&&(_, a), &&(_, b)| {
                let na = &self.nodes[a];
                let nb = &self.nodes[b];
                na.n.cmp(&nb.n)
                    .then(na.v.partial_cmp(&nb.v).unwrap_or(std::cmp::Ordering::Equal))
            })
            .map(|&(action, _)| action)
    }

    /// (action, N, V) rows for the root's children, for diagnostics.
    pub fn root_child_stats(&self) -> Vec<(usize, u32, f64)> {
        self.nodes[Self::ROOT]
            .children
            .iter()
            .map(|&(a, id)| (a, self.nodes[id].n, self.nodes[id].v))
            .collect()
    }

    /// (action, N, O, V) rows for the root's children — the full WU-UCT
    /// root statistics the `inspect` summary is built from. O(children).
    pub fn root_child_full_stats(&self) -> Vec<(usize, u32, u32, f64)> {
        self.nodes[Self::ROOT]
            .children
            .iter()
            .map(|&(a, id)| {
                let n = &self.nodes[id];
                (a, n.n, n.o, n.v)
            })
            .collect()
    }

    /// Depth of the deepest node. O(1): maintained on insert and re-root.
    pub fn max_depth(&self) -> u32 {
        self.deepest
    }

    /// Structural invariants, asserted by tests and property checks:
    /// 1. every non-root node's parent lists it as a child exactly once;
    /// 2. `N` of an internal node ≥ sum of children's `N` (each rollout
    ///    backing up through a child also backs up through the parent);
    /// 3. all `O` are zero when no simulations are in flight (checked by
    ///    callers at quiescence via [`Tree::total_unobserved`]).
    pub fn check_invariants(&self) {
        for (id, node) in self.nodes.iter().enumerate() {
            if let Some(p) = node.parent {
                let count = self.nodes[p]
                    .children
                    .iter()
                    .filter(|&&(_, c)| c == id)
                    .count();
                assert_eq!(count, 1, "node {id} not exactly once under parent {p}");
            }
            let child_n: u32 = node.children.iter().map(|&(_, c)| self.nodes[c].n).sum();
            assert!(
                node.n >= child_n,
                "node {id}: N={} < sum of children N={child_n}",
                node.n
            );
        }
    }

    /// Sum of `O` over all nodes (must be 0 at quiescence).
    pub fn total_unobserved(&self) -> u64 {
        self.nodes.iter().map(|n| n.o as u64).sum()
    }

    /// Re-root the tree at the root's child reached by `action`, discarding
    /// every off-path subtree and preserving the retained nodes' full
    /// statistics ({N, V, O}, rewards, untried lists, stored snapshots).
    /// Depths are rebased so the new root sits at depth 0 (the depth cap
    /// keeps meaning "plies below the current root" across moves).
    ///
    /// Returns the retained node count, or `None` when `action` was never
    /// expanded — the caller then starts a fresh tree. Must only be called
    /// at quiescence (no in-flight rollouts, i.e. `ΣO` on discarded paths
    /// would otherwise leak).
    pub fn advance_root(&mut self, action: usize) -> Option<usize> {
        let new_root = self.nodes[Self::ROOT].child_for(action)?;
        // BFS over the retained subtree, building old-id → new-id.
        const UNMAPPED: usize = usize::MAX;
        let mut map = vec![UNMAPPED; self.nodes.len()];
        let mut order = vec![new_root];
        map[new_root] = 0;
        let mut i = 0;
        while i < order.len() {
            let old = order[i];
            i += 1;
            for &(_, c) in &self.nodes[old].children {
                map[c] = order.len();
                order.push(c);
            }
        }
        let depth_base = self.nodes[new_root].depth;
        let mut kept = Vec::with_capacity(order.len());
        let mut deepest = 0;
        for &old in &order {
            // Move nodes out (snapshots can be large; no clones).
            let mut n = std::mem::replace(&mut self.nodes[old], Node::new(None, 0, 0));
            n.parent = n.parent.map(|p| map[p]);
            for (_, c) in n.children.iter_mut() {
                *c = map[*c];
            }
            n.depth -= depth_base;
            deepest = deepest.max(n.depth);
            kept.push(n);
        }
        kept[0].parent = None;
        kept[0].action = 0;
        kept[0].reward = 0.0;
        self.nodes = kept;
        self.deepest = deepest;
        self.soa_reset(); // every id was remapped; no row survives
        Some(self.nodes.len())
    }

    /// Iterate over all nodes with ids.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &Node)> {
        self.nodes.iter().enumerate()
    }

    /// Rebuild a tree from raw nodes — the store codec's decode path.
    ///
    /// Unlike the rest of this type, the input is **untrusted** (bytes
    /// off a disk), so every structural invariant is checked and
    /// violations come back as `Err(reason)` instead of a panic: node 0
    /// must be a parentless depth-0 root, every other node must be
    /// listed exactly once in its parent's child map with a matching
    /// edge action and `depth = parent + 1` (which also rules out
    /// cycles), child actions must be unique per node, and `N` must
    /// dominate the children's sum (invariant 2 of
    /// [`Tree::check_invariants`]).
    pub fn from_nodes(nodes: Vec<Node>) -> Result<Tree, &'static str> {
        if nodes.is_empty() {
            return Err("empty node list");
        }
        if nodes[0].parent.is_some() {
            return Err("root has a parent");
        }
        if nodes[0].depth != 0 {
            return Err("root depth is not zero");
        }
        let mut linked = vec![0usize; nodes.len()];
        for (id, node) in nodes.iter().enumerate() {
            let mut actions: Vec<usize> =
                node.children.iter().map(|&(a, _)| a).collect();
            actions.sort_unstable();
            if actions.windows(2).any(|w| w[0] == w[1]) {
                return Err("duplicate child action");
            }
            let mut child_n: u64 = 0;
            for &(action, child) in &node.children {
                if child == 0 || child >= nodes.len() {
                    return Err("child id out of range");
                }
                linked[child] += 1;
                let c = &nodes[child];
                if c.parent != Some(id) {
                    return Err("child's parent link mismatch");
                }
                if c.action != action {
                    return Err("child's edge action mismatch");
                }
                if c.depth != node.depth + 1 {
                    return Err("child depth mismatch");
                }
                child_n += c.n as u64;
            }
            if (node.n as u64) < child_n {
                return Err("parent N below children's sum");
            }
        }
        if linked.iter().skip(1).any(|&seen| seen != 1) {
            return Err("node not linked exactly once");
        }
        let deepest = nodes.iter().map(|n| n.depth).max().unwrap_or(0);
        Ok(Tree { nodes, deepest, soa: Mutex::default() })
    }
}

impl Default for Tree {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_tree_has_root_only() {
        let t = Tree::new();
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        assert_eq!(t.node(Tree::ROOT).depth, 0);
    }

    #[test]
    fn add_child_links_both_ways() {
        let mut t = Tree::new();
        let c = t.add_child(Tree::ROOT, 3);
        assert_eq!(t.node(c).parent, Some(Tree::ROOT));
        assert_eq!(t.node(c).action, 3);
        assert_eq!(t.node(c).depth, 1);
        assert_eq!(t.node(Tree::ROOT).child_for(3), Some(c));
    }

    #[test]
    #[should_panic(expected = "already expanded")]
    fn duplicate_action_panics() {
        let mut t = Tree::new();
        t.add_child(Tree::ROOT, 1);
        t.add_child(Tree::ROOT, 1);
    }

    #[test]
    fn path_to_root_orders_leaf_first() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(a, 1);
        let c = t.add_child(b, 2);
        assert_eq!(t.path_to_root(c), vec![c, b, a, Tree::ROOT]);
    }

    #[test]
    fn for_path_applies_to_all_ancestors() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(a, 1);
        t.for_path_to_root(b, |n| n.o += 1);
        assert_eq!(t.node(b).o, 1);
        assert_eq!(t.node(a).o, 1);
        assert_eq!(t.node(Tree::ROOT).o, 1);
        assert_eq!(t.total_unobserved(), 3);
    }

    #[test]
    fn best_root_action_prefers_visits_then_value() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(Tree::ROOT, 1);
        t.node_mut(a).n = 5;
        t.node_mut(a).v = 0.1;
        t.node_mut(b).n = 9;
        t.node_mut(b).v = 0.0;
        assert_eq!(t.best_root_action(), Some(1));
        // Tie on N: value breaks it.
        t.node_mut(a).n = 9;
        assert_eq!(t.best_root_action(), Some(0));
    }

    #[test]
    fn best_root_action_none_without_children() {
        assert_eq!(Tree::new().best_root_action(), None);
    }

    #[test]
    fn invariants_hold_after_simulated_backups() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(a, 0);
        // Back up two rollouts through b, one through a only.
        for id in [b, a, Tree::ROOT] {
            t.node_mut(id).observe(1.0);
        }
        for id in [b, a, Tree::ROOT] {
            t.node_mut(id).observe(0.5);
        }
        t.node_mut(a).observe(0.0);
        t.node_mut(Tree::ROOT).observe(0.0);
        t.check_invariants();
    }

    #[test]
    #[should_panic(expected = "N=")]
    fn invariant_catches_undercounted_parent() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        t.node_mut(a).n = 5; // parent root still has N=0
        t.check_invariants();
    }

    #[test]
    fn advance_root_keeps_subtree_stats_and_rebases_depth() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(Tree::ROOT, 1);
        let c = t.add_child(a, 2);
        t.node_mut(a).n = 7;
        t.node_mut(a).v = 1.25;
        t.node_mut(a).untried = vec![5, 6];
        t.node_mut(b).n = 3;
        t.node_mut(c).n = 4;
        t.node_mut(c).v = -0.5;
        t.node_mut(c).reward = 2.0;
        let kept = t.advance_root(0);
        assert_eq!(kept, Some(2), "a and c survive, b is discarded");
        assert_eq!(t.len(), 2);
        let root = t.node(Tree::ROOT);
        assert_eq!(root.parent, None);
        assert_eq!(root.n, 7);
        assert_eq!(root.v, 1.25);
        assert_eq!(root.untried, vec![5, 6]);
        assert_eq!(root.depth, 0);
        let c_new = root.child_for(2).expect("kept child");
        assert_eq!(t.node(c_new).n, 4);
        assert_eq!(t.node(c_new).v, -0.5);
        assert_eq!(t.node(c_new).reward, 2.0);
        assert_eq!(t.node(c_new).depth, 1);
        assert_eq!(t.node(c_new).parent, Some(Tree::ROOT));
        t.check_invariants();
    }

    #[test]
    fn advance_root_unexpanded_action_returns_none() {
        let mut t = Tree::new();
        t.add_child(Tree::ROOT, 0);
        assert_eq!(t.advance_root(3), None);
        assert_eq!(t.len(), 2, "tree untouched on miss");
    }

    #[test]
    fn advance_root_twice_walks_a_path() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 1);
        let b = t.add_child(a, 4);
        t.add_child(b, 2);
        t.node_mut(b).n = 9;
        assert_eq!(t.advance_root(1), Some(3));
        assert_eq!(t.advance_root(4), Some(2));
        assert_eq!(t.node(Tree::ROOT).n, 9);
        assert_eq!(t.max_depth(), 1);
        t.check_invariants();
    }

    #[test]
    fn from_nodes_accepts_a_valid_tree_and_preserves_it() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(a, 2);
        t.node_mut(b).n = 3;
        t.node_mut(a).n = 5;
        t.node_mut(Tree::ROOT).n = 5;
        let nodes: Vec<Node> = t.iter().map(|(_, n)| n.clone()).collect();
        let rebuilt = Tree::from_nodes(nodes).expect("valid tree");
        assert_eq!(rebuilt.len(), 3);
        assert_eq!(rebuilt.node(b).n, 3);
        rebuilt.check_invariants();
    }

    #[test]
    fn from_nodes_rejects_structural_damage() {
        // Root with a parent.
        assert!(Tree::from_nodes(vec![Node::new(Some(0), 0, 0)]).is_err());
        // Empty list.
        assert!(Tree::from_nodes(Vec::new()).is_err());
        // Child out of range.
        let mut root = Node::new(None, 0, 0);
        root.children.push((0, 5));
        assert!(Tree::from_nodes(vec![root]).is_err());
        // Orphan node (never linked).
        let orphan = vec![Node::new(None, 0, 0), Node::new(Some(0), 1, 1)];
        assert!(Tree::from_nodes(orphan).is_err());
        // Depth mismatch.
        let mut root = Node::new(None, 0, 0);
        root.children.push((1, 1));
        let child = Node::new(Some(0), 1, 7);
        assert!(Tree::from_nodes(vec![root, child]).is_err());
        // Parent undercounts its children.
        let mut root = Node::new(None, 0, 0);
        root.children.push((1, 1));
        let mut child = Node::new(Some(0), 1, 1);
        child.n = 9;
        assert!(Tree::from_nodes(vec![root, child]).is_err());
        // Duplicate action under one parent.
        let mut root = Node::new(None, 0, 0);
        root.children.push((1, 1));
        root.children.push((1, 2));
        let c1 = Node::new(Some(0), 1, 1);
        let c2 = Node::new(Some(0), 1, 1);
        assert!(Tree::from_nodes(vec![root, c1, c2]).is_err());
    }

    #[test]
    fn child_lanes_track_mutations_and_survive_rerooting() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 2);
        let b = t.add_child(Tree::ROOT, 0);
        // Cold read: lanes follow children order (insertion), not action.
        t.with_child_lanes(Tree::ROOT, |l| {
            assert_eq!(l.ids, vec![a, b]);
            assert_eq!(l.n, vec![0, 0]);
        });
        // Every mutation route dirties the affected row.
        t.node_mut(a).n = 7;
        t.node_mut(a).v = 0.5;
        t.for_path_to_root(b, |n| n.o += 3);
        t.with_child_lanes(Tree::ROOT, |l| {
            assert_eq!(l.n, vec![7, 0]);
            assert_eq!(l.o, vec![0, 3], "b's in-flight count lands in lane 1");
            assert_eq!(l.v, vec![0.5, 0.0]);
        });
        // Re-rooting remaps every id; the mirror rebuilds from scratch.
        let c = t.add_child(a, 1);
        t.node_mut(c).n = 4;
        t.node_mut(a).n = 9;
        t.advance_root(2);
        t.with_child_lanes(Tree::ROOT, |l| {
            assert_eq!(l.ids.len(), 1);
            assert_eq!(l.n, vec![4]);
        });
        // Clones start cold and rebuild against their own nodes.
        let u = t.clone();
        u.with_child_lanes(Tree::ROOT, |l| assert_eq!(l.n, vec![4]));
    }

    #[test]
    fn max_depth_tracks_deepest() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(a, 0);
        t.add_child(b, 0);
        assert_eq!(t.max_depth(), 3);
    }
}
