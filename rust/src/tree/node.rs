//! Search-tree node: the statistics triple {V, N, O} plus bookkeeping.
//!
//! `N` counts *observed* samples (completed simulations backed up through
//! the node, Eq. 3); `O` counts *unobserved* samples — rollouts that were
//! initiated through this node but whose simulation has not returned yet
//! (the paper's central quantity, Eqs. 5–6). `vloss`/`vcount` hold the
//! virtual-loss accumulators used only by the TreeP baselines (Algorithm 5
//! and Eq. 7).

use crate::env::EnvState;

/// Index of a node inside its [`super::arena::Tree`].
pub type NodeId = usize;

/// One search-tree node.
///
/// `PartialEq` compares every field bit-for-bit (f64 by `==`) — the store's
/// delta encoder uses it to detect nodes changed since the previous
/// snapshot, so "equal" must mean "nothing to re-serialize".
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// Parent node; `None` for the root.
    pub parent: Option<NodeId>,
    /// Action on the edge parent -> this node (0 for the root).
    pub action: usize,
    /// Expanded children as (action, child id) pairs.
    pub children: Vec<(usize, NodeId)>,
    /// Observed visit count `N_s`.
    pub n: u32,
    /// Unobserved (in-flight) sample count `O_s`.
    pub o: u32,
    /// Running mean value estimate `V_s` (Eq. 3).
    pub v: f64,
    /// Immediate reward `R(parent, action)` collected when expanding.
    pub reward: f64,
    /// Whether the environment reported `done` on the edge into this node.
    pub terminal: bool,
    /// Depth (root = 0).
    pub depth: u32,
    /// Legal actions not yet expanded into children.
    pub untried: Vec<usize>,
    /// Snapshot of the environment at this node (the centralized
    /// game-state storage of Appendix A). Dropped for exhausted nodes by
    /// the master to bound memory.
    pub state: Option<EnvState>,
    /// TreeP virtual-loss accumulator (sum of subtracted r_VL).
    pub vloss: f64,
    /// TreeP virtual pseudo-count accumulator (Eq. 7's n_VL sum).
    pub vcount: u32,
}

impl Node {
    /// Fresh node under `parent` via `action`.
    pub fn new(parent: Option<NodeId>, action: usize, depth: u32) -> Node {
        Node {
            parent,
            action,
            children: Vec::new(),
            n: 0,
            o: 0,
            v: 0.0,
            reward: 0.0,
            terminal: false,
            depth,
            untried: Vec::new(),
            state: None,
            vloss: 0.0,
            vcount: 0,
        }
    }

    /// `N_s + O_s`, the corrected visit total of Eq. 4.
    pub fn total_visits(&self) -> u32 {
        self.n + self.o
    }

    /// Child id reached by `action`, if expanded.
    pub fn child_for(&self, action: usize) -> Option<NodeId> {
        self.children
            .iter()
            .find(|&&(a, _)| a == action)
            .map(|&(_, id)| id)
    }

    /// Is every legal action expanded?
    pub fn fully_expanded(&self) -> bool {
        self.untried.is_empty()
    }

    /// Leaf = no children yet.
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }

    /// Incorporate one observed return `r̄` into the running mean (Eq. 3's
    /// value update; the caller increments `n` via complete-update logic).
    pub fn observe(&mut self, ret: f64) {
        self.n += 1;
        self.v += (ret - self.v) / self.n as f64;
    }

    /// Effective value under TreeP's virtual loss / pseudo-count (Eq. 7):
    /// `V' = (N·V − vloss) / (N + vcount)`; plain `V` when no virtual
    /// adjustments are outstanding.
    pub fn effective_v(&self) -> f64 {
        if self.vloss == 0.0 && self.vcount == 0 {
            return self.v;
        }
        let denom = self.n as f64 + self.vcount as f64;
        if denom == 0.0 {
            return -self.vloss; // unvisited but virtually-lossed
        }
        (self.n as f64 * self.v - self.vloss) / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_node_is_pristine_leaf() {
        let n = Node::new(None, 0, 0);
        assert!(n.is_leaf());
        assert!(n.fully_expanded()); // no untried set yet
        assert_eq!(n.total_visits(), 0);
        assert_eq!(n.effective_v(), 0.0);
    }

    #[test]
    fn observe_computes_running_mean() {
        let mut n = Node::new(None, 0, 0);
        n.observe(2.0);
        n.observe(4.0);
        n.observe(6.0);
        assert_eq!(n.n, 3);
        assert!((n.v - 4.0).abs() < 1e-12);
    }

    #[test]
    fn total_visits_adds_unobserved() {
        let mut n = Node::new(None, 0, 0);
        n.observe(1.0);
        n.o = 3;
        assert_eq!(n.total_visits(), 4);
    }

    #[test]
    fn child_lookup() {
        let mut n = Node::new(None, 0, 0);
        n.children.push((2, 7));
        n.children.push((5, 9));
        assert_eq!(n.child_for(5), Some(9));
        assert_eq!(n.child_for(3), None);
    }

    #[test]
    fn effective_v_matches_eq7() {
        let mut n = Node::new(None, 0, 0);
        n.observe(1.0);
        n.observe(1.0); // N=2, V=1
        n.vloss = 1.0;
        n.vcount = 1;
        // (2*1 - 1) / (2 + 1) = 1/3
        assert!((n.effective_v() - 1.0 / 3.0).abs() < 1e-12);
        n.vloss = 0.0;
        n.vcount = 0;
        assert_eq!(n.effective_v(), 1.0);
    }

    #[test]
    fn effective_v_unvisited_with_vloss() {
        let mut n = Node::new(None, 0, 0);
        n.vloss = 2.5;
        assert_eq!(n.effective_v(), -2.5);
    }
}
