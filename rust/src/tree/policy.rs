//! Tree policies: UCT (Eq. 2), WU-UCT (Eq. 4) and the virtual-loss
//! variants used by the TreeP baselines.
//!
//! All selection ultimately funnels through [`select_child`], which scores
//! the expanded children of a node and returns the argmax; unvisited
//! children (total 0) score `+inf` (first-visit priority), matching the
//! semantics of the L1 Pallas scorer (`python/compile/kernels/
//! wu_uct_score.py`) that the `micro_hotpath` bench cross-checks.

use crate::tree::arena::Tree;
use crate::tree::node::NodeId;

/// Which statistics the score uses — i.e. which paper algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScoreMode {
    /// Plain UCT (Eq. 2): V + β√(2 ln N_s / N_s').
    Uct,
    /// WU-UCT (Eq. 4): V + β√(2 ln (N_s+O_s) / (N_s'+O_s')).
    WuUct,
    /// TreeP: UCT over virtual-loss-adjusted values (Algorithm 5 / Eq. 7;
    /// the accumulators live in the nodes).
    VirtualLoss,
}

/// Exploration score of a child given parent totals.
///
/// `parent_total` is `N_s` (+ `O_s` under WU-UCT); `child_total` likewise.
/// `value` is the child's (possibly virtual-loss-adjusted) mean value.
#[inline]
pub fn ucb_score(value: f64, parent_total: u32, child_total: u32, beta: f64) -> f64 {
    if child_total == 0 {
        return f64::INFINITY;
    }
    let log_term = (parent_total.max(1) as f64).ln();
    value + beta * (2.0 * log_term / child_total as f64).sqrt()
}

/// Score `child` under `mode`.
#[inline]
pub fn score_child(tree: &Tree, parent: NodeId, child: NodeId, mode: ScoreMode, beta: f64) -> f64 {
    let p = tree.node(parent);
    let c = tree.node(child);
    match mode {
        ScoreMode::Uct => ucb_score(c.v, p.n, c.n, beta),
        ScoreMode::WuUct => ucb_score(c.v, p.total_visits(), c.total_visits(), beta),
        ScoreMode::VirtualLoss => {
            // Virtual pseudo-counts also inflate the visit totals (Eq. 7).
            let pt = p.n + p.vcount;
            let ct = c.n + c.vcount;
            ucb_score(c.effective_v(), pt, ct, beta)
        }
    }
}

/// Argmax child of `parent` under `mode`; `None` if no children.
/// Deterministic tie-break: first (lowest-index) child wins, matching the
/// L1 kernel's `argmax` semantics — this determinism is precisely what
/// causes the *collapse of exploration* under naive parallelization
/// (Fig. 1c), which WU-UCT's `O` statistics then counteract.
///
/// This is the SoA fast path: it scans the parent's [`ChildLanes`] — flat
/// per-child `u32`/`f64` slices — with the parent's log term hoisted out
/// of the loop, instead of scoring one `Node` at a time. The result is
/// bit-identical to [`select_child_scalar`] (same operations in the same
/// order per child, `s <= best` skip semantics included), which the
/// `soa_selection_matches_scalar` property test enforces over randomized
/// trees for all three modes.
pub fn select_child(tree: &Tree, parent: NodeId, mode: ScoreMode, beta: f64) -> Option<NodeId> {
    let p = tree.node(parent);
    if p.children.is_empty() {
        return None;
    }
    let parent_total = match mode {
        ScoreMode::Uct => p.n,
        ScoreMode::WuUct => p.total_visits(),
        ScoreMode::VirtualLoss => p.n + p.vcount,
    };
    let log_term = (parent_total.max(1) as f64).ln();
    tree.with_child_lanes(parent, |lanes| {
        // One specialized scan per mode keeps the inner loop branch-free
        // over flat lanes; each mirrors `ucb_score`'s arithmetic exactly.
        let score = |k: usize| -> f64 {
            let (value, child_total) = match mode {
                ScoreMode::Uct => (lanes.v[k], lanes.n[k]),
                ScoreMode::WuUct => (lanes.v[k], lanes.n[k] + lanes.o[k]),
                ScoreMode::VirtualLoss => {
                    let value = if lanes.vloss[k] == 0.0 && lanes.vcount[k] == 0 {
                        lanes.v[k]
                    } else {
                        let denom = lanes.n[k] as f64 + lanes.vcount[k] as f64;
                        if denom == 0.0 {
                            -lanes.vloss[k]
                        } else {
                            (lanes.n[k] as f64 * lanes.v[k] - lanes.vloss[k]) / denom
                        }
                    };
                    (value, lanes.n[k] + lanes.vcount[k])
                }
            };
            if child_total == 0 {
                return f64::INFINITY;
            }
            value + beta * (2.0 * log_term / child_total as f64).sqrt()
        };
        let mut best_k = 0;
        let mut best_s = score(0);
        for k in 1..lanes.ids.len() {
            let s = score(k);
            // `!(s <= best)` — not `s > best` — replicates the scalar
            // loop's behavior bit-for-bit, NaN handling included.
            if !(s <= best_s) {
                best_s = s;
                best_k = k;
            }
        }
        Some(lanes.ids[best_k])
    })
}

/// The pre-SoA argmax: score children one [`Node`] at a time through
/// [`score_child`]. Kept as the semantic reference [`select_child`] must
/// match and as the baseline the `micro_hotpath` bench compares against.
pub fn select_child_scalar(
    tree: &Tree,
    parent: NodeId,
    mode: ScoreMode,
    beta: f64,
) -> Option<NodeId> {
    let node = tree.node(parent);
    let mut best: Option<(NodeId, f64)> = None;
    for &(_, child) in &node.children {
        let s = score_child(tree, parent, child, mode, beta);
        match best {
            Some((_, bs)) if s <= bs => {}
            _ => best = Some((child, s)),
        }
    }
    best.map(|(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree_with_two_children() -> (Tree, NodeId, NodeId) {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(Tree::ROOT, 1);
        (t, a, b)
    }

    #[test]
    fn unvisited_child_scores_infinity() {
        let (t, a, _) = tree_with_two_children();
        let s = score_child(&t, Tree::ROOT, a, ScoreMode::Uct, 1.0);
        assert!(s.is_infinite());
    }

    #[test]
    fn higher_value_wins_when_counts_equal() {
        let (mut t, a, b) = tree_with_two_children();
        for id in [a, b] {
            t.node_mut(id).n = 10;
        }
        t.node_mut(Tree::ROOT).n = 20;
        t.node_mut(a).v = 0.9;
        t.node_mut(b).v = 0.1;
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 1.0), Some(a));
    }

    #[test]
    fn exploration_term_prefers_less_visited() {
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 100;
        t.node_mut(a).n = 90;
        t.node_mut(b).n = 10;
        // Equal values: exploration should pick the rarely-visited child.
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 1.0), Some(b));
    }

    #[test]
    fn wu_uct_counts_inflight_simulations() {
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 100;
        t.node_mut(a).n = 10;
        t.node_mut(b).n = 10;
        // Under plain UCT the tie goes to `a` (first child).
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 1.0), Some(a));
        // 5 simulations in flight on `a`: WU-UCT diverts to `b` —
        // the fix for the collapse of exploration.
        t.node_mut(a).o = 5;
        t.node_mut(Tree::ROOT).o = 5;
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::WuUct, 1.0), Some(b));
        // Plain UCT is blind to O and still picks `a`.
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 1.0), Some(a));
    }

    #[test]
    fn wu_uct_penalty_vanishes_for_large_n() {
        // Exploitation preserved: with N huge, O barely moves the score
        // (the paper's argument that WU-UCT avoids exploitation failure).
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 2_000_000;
        t.node_mut(a).n = 1_000_000;
        t.node_mut(a).v = 0.51; // clearly best
        t.node_mut(b).n = 1_000_000;
        t.node_mut(b).v = 0.5;
        t.node_mut(a).o = 16;
        t.node_mut(Tree::ROOT).o = 16;
        assert_eq!(
            select_child(&t, Tree::ROOT, ScoreMode::WuUct, 1.0),
            Some(a),
            "all 16 workers may exploit the best child"
        );
    }

    #[test]
    fn virtual_loss_diverts_like_treep() {
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 100;
        for id in [a, b] {
            t.node_mut(id).n = 50;
            t.node_mut(id).v = 0.5;
        }
        // virtual loss on `a` pushes its effective value down hard.
        t.node_mut(a).vloss = 5.0;
        t.node_mut(a).vcount = 1;
        assert_eq!(
            select_child(&t, Tree::ROOT, ScoreMode::VirtualLoss, 1.0),
            Some(b)
        );
    }

    #[test]
    fn treep_hard_penalty_causes_exploitation_failure() {
        // The contrast case from Section 4: even when `a` is *clearly*
        // optimal, a large-enough virtual loss diverts workers off it —
        // exploitation failure. WU-UCT (above) does not have this failure.
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 10_000;
        t.node_mut(a).n = 9_000;
        t.node_mut(a).v = 0.9;
        t.node_mut(b).n = 1_000;
        t.node_mut(b).v = 0.1;
        t.node_mut(a).vloss = 9_000.0; // r_VL = 1.0 x 9000... no: one 9000-strong loss
        assert_eq!(
            select_child(&t, Tree::ROOT, ScoreMode::VirtualLoss, 1.0),
            Some(b)
        );
    }

    #[test]
    fn beta_zero_is_greedy() {
        let (mut t, a, b) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 100;
        t.node_mut(a).n = 1;
        t.node_mut(a).v = 0.2;
        t.node_mut(b).n = 99;
        t.node_mut(b).v = 0.8;
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 0.0), Some(b));
    }

    #[test]
    fn no_children_gives_none() {
        let t = Tree::new();
        assert_eq!(select_child(&t, Tree::ROOT, ScoreMode::Uct, 1.0), None);
    }

    #[test]
    fn scores_match_l1_kernel_convention() {
        // Mirror of python/tests/test_kernels.py::test_matches_ref for a
        // hand-computed case: V=0.3, parent total 50, child total 5, β=1.
        let (mut t, a, _) = tree_with_two_children();
        t.node_mut(Tree::ROOT).n = 45;
        t.node_mut(Tree::ROOT).o = 5;
        t.node_mut(a).n = 4;
        t.node_mut(a).o = 1;
        t.node_mut(a).v = 0.3;
        let s = score_child(&t, Tree::ROOT, a, ScoreMode::WuUct, 1.0);
        let want = 0.3 + (2.0 * (50f64).ln() / 5.0).sqrt();
        assert!((s - want).abs() < 1e-12);
    }
}
