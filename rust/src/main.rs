//! `wu-uct` — the leader CLI: run searches, gameplays and every paper
//! experiment from one binary.
//!
//! ```text
//! wu-uct search        one search on a named environment
//! wu-uct play          interactive anytime demo: a fixed time budget of
//!                      deadline-bounded thinking per frame, best action
//!                      taken when the clock expires (--ticks bounds it)
//! wu-uct serve         multi-session search service over TCP (JSON lines);
//!                      with --hosts a:p,b:p it becomes a stateless router
//!                      over remote shard hosts
//! wu-uct shard-host    one session-hosting process for a router tier
//! wu-uct flight        reconstruct a post-mortem timeline from a dead
//!                      process's --flight-dir segments
//! wu-uct top           live terminal dashboard polling a serve/router
//!                      address (metrics + optional per-session inspect)
//! wu-uct atari-table1  Table 1 (+ Fig. 10 with --relative)
//! wu-uct atari-fig5    Fig. 5 worker sweep
//! wu-uct treep-ablation  Table 5 TreeP-variant comparison
//! wu-uct sweep-speedup Fig. 4 curves (+ Table 3 grid with --grid)
//! wu-uct breakdown     Fig. 2 time breakdown
//! wu-uct passrate      Table 2 + Fig. 8 pass-rate system
//! wu-uct policy-eval   Table 4 policy-only floor
//! ```

use anyhow::{bail, Result};
use wu_uct::env::{atari, tapgame::Level, tapgame::TapGame, Env};
use wu_uct::experiments::{self, Scale};
use wu_uct::mcts::{by_name, SearchSpec};
use wu_uct::passrate::SystemConfig;
use wu_uct::service::{
    QosClass, ServiceConfig, SessionOptions, ShardedConfig, ShardedService, StatsServer,
    TcpServer,
};
use wu_uct::util::cli::{usage, Args, OptSpec};

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "scale", help: "quick | paper", default: Some("quick") },
        OptSpec { name: "env", help: "game name or level-35 / level-58", default: Some("Breakout") },
        OptSpec { name: "algo", help: "WU-UCT | UCT | LeafP | TreeP | RootP", default: Some("WU-UCT") },
        OptSpec { name: "workers", help: "simulation workers", default: Some("8") },
        OptSpec { name: "exp-workers", help: "expansion workers", default: Some("1") },
        OptSpec { name: "sims", help: "simulations per search (0 = scale default)", default: Some("0") },
        OptSpec { name: "trials", help: "episodes per cell (0 = scale default)", default: Some("0") },
        OptSpec { name: "games", help: "comma list of games (empty = paper set)", default: Some("") },
        OptSpec { name: "seed", help: "base rng seed", default: Some("0") },
        OptSpec { name: "out", help: "CSV output path (empty = none)", default: Some("") },
        OptSpec { name: "repeats", help: "timing repeats for speedup cells", default: Some("2") },
        OptSpec { name: "relative", help: "also print Fig 10 relative bars", default: None },
        OptSpec { name: "grid", help: "full Table 3 grid (else Fig 4 curves)", default: None },
        OptSpec { name: "addr", help: "serve: TCP listen address", default: Some("127.0.0.1:3771") },
        OptSpec { name: "shards", help: "serve: scheduler shards", default: Some("1") },
        OptSpec {
            name: "max-sessions",
            help: "serve: open-session cap per shard (0 = unlimited)",
            default: Some("0"),
        },
        OptSpec { name: "no-steal", help: "serve: disable cross-shard work stealing", default: None },
        OptSpec {
            name: "max-conns",
            help: "serve: cap on concurrent TCP connections; beyond it new ones get one busy line (0 = unlimited)",
            default: Some("0"),
        },
        OptSpec {
            name: "data-dir",
            help: "serve: durable session store directory (empty = memory-only)",
            default: Some(""),
        },
        OptSpec {
            name: "snapshot-every",
            help: "serve: WAL tree-snapshot cadence in thinks per session",
            default: Some("1"),
        },
        OptSpec {
            name: "full-every",
            help: "serve: every Nth WAL snapshot is a full image, the rest are deltas (1 = all full)",
            default: Some("8"),
        },
        OptSpec {
            name: "rebalance",
            help: "serve: auto-migrate sessions when shard occupancy skew exceeds this factor (0 = off)",
            default: Some("0"),
        },
        OptSpec {
            name: "hosts",
            help: "serve: comma list of shard-host addresses; makes serve a stateless router over them",
            default: Some(""),
        },
        OptSpec {
            name: "stats-addr",
            help: "serve: Prometheus text scrape address (empty = off)",
            default: Some(""),
        },
        OptSpec {
            name: "replicate",
            help: "serve: stream every shard WAL to this standby host (host:port; requires --data-dir)",
            default: Some(""),
        },
        OptSpec {
            name: "repl-ack",
            help: "serve: hold each reply until the standby acked the records behind it",
            default: None,
        },
        OptSpec {
            name: "max-held",
            help: "serve: cap on replies parked per shard awaiting fsync/standby ack (0 = uncapped)",
            default: Some("0"),
        },
        OptSpec {
            name: "journal-cap",
            help: "serve: per-shard event-journal ring capacity (oldest evicted beyond)",
            default: Some("4096"),
        },
        OptSpec {
            name: "flight-dir",
            help: "serve: spill journal events to checksummed segments under this dir; flight: the dir to replay",
            default: Some(""),
        },
        OptSpec {
            name: "session",
            help: "flight/top: session id to focus on (0 = all / none)",
            default: Some("0"),
        },
        OptSpec { name: "topk", help: "top: root actions shown per inspect", default: Some("5") },
        OptSpec {
            name: "ticks",
            help: "top/play: this many refreshes/frames then exit (0 = until killed / terminal)",
            default: Some("0"),
        },
        OptSpec {
            name: "interval-ms",
            help: "top: refresh interval; play: per-frame thinking budget (ms)",
            default: Some("1000"),
        },
        OptSpec {
            name: "join",
            help: "shard-host: register with this router and heartbeat it (host:port)",
            default: Some(""),
        },
        OptSpec {
            name: "advertise",
            help: "shard-host: address to advertise at join (empty = --addr)",
            default: Some(""),
        },
        OptSpec {
            name: "suspect-after-ms",
            help: "router: a joined host silent this long turns suspect (standby promoted if advertised)",
            default: Some("3000"),
        },
        OptSpec {
            name: "lease-ttl-ms",
            help: "router: session-lease TTL; a router stalled past it can be fenced by a peer",
            default: Some("5000"),
        },
        OptSpec { name: "help", help: "show usage", default: None },
    ]
}

fn scale_from(args: &Args) -> Result<Scale> {
    let mut scale = match args.str("scale")? {
        "paper" => Scale::paper(),
        _ => Scale::quick(),
    };
    scale.seed = args.u64("seed")?;
    let trials = args.usize("trials")?;
    if trials > 0 {
        scale.trials = trials;
    }
    let sims = args.u32("sims")?;
    if sims > 0 {
        scale.max_simulations = sims;
    }
    scale.workers = args.usize("workers")?;
    Ok(scale)
}

fn games_from(args: &Args, default: &[&str]) -> Vec<String> {
    let listed = args.get("games").unwrap_or("");
    if listed.is_empty() {
        default.iter().map(|s| s.to_string()).collect()
    } else {
        listed.split(',').map(|s| s.trim().to_string()).collect()
    }
}

fn make_env(name: &str, seed: u64) -> Box<dyn Env> {
    match name {
        "level-35" => Box::new(TapGame::new(Level::level35(), seed)),
        "level-58" => Box::new(TapGame::new(Level::level58(), seed)),
        other => atari::make(other, seed),
    }
}

fn emit(table: &wu_uct::util::table::Table, out: &str) -> Result<()> {
    print!("{}", table.render());
    if !out.is_empty() {
        table.write_csv(out)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

/// `wu-uct flight`: reconstruct post-mortem timelines from the segment
/// files a (possibly SIGKILLed) serve process spilled under
/// `--flight-dir`. Events merge across the per-shard subdirectories
/// into one `at_us`-ordered stream, then print grouped by session so an
/// investigation reads each think's admit → durable → reply_sent arc
/// top to bottom.
fn run_flight(dir: &str, session_filter: u64) -> Result<()> {
    let replay = wu_uct::obs::replay_flight_tree(std::path::Path::new(dir))?;
    println!(
        "flight replay: {} event(s) from {} segment(s) under {dir}{}",
        replay.events.len(),
        replay.segments,
        if replay.torn_tail { " (torn final frame dropped — process died mid-write)" } else { "" }
    );
    let mut sessions: Vec<u64> = Vec::new();
    for ev in &replay.events {
        if !sessions.contains(&ev.session) {
            sessions.push(ev.session);
        }
    }
    if session_filter != 0 {
        sessions.retain(|&s| s == session_filter);
        if sessions.is_empty() {
            bail!("flight: no events for session {session_filter} under {dir}");
        }
    }
    for sid in sessions {
        let events: Vec<_> = replay.events.iter().filter(|e| e.session == sid).collect();
        let t0 = events.first().map(|e| e.at_us).unwrap_or(0);
        println!("session {sid}: {} event(s)", events.len());
        for ev in events {
            println!(
                "  +{:>10}us  task {:>4}  trace {:>4}  {:<15} arg={}",
                ev.at_us.saturating_sub(t0),
                ev.task,
                ev.trace,
                ev.kind.name(),
                ev.arg
            );
        }
    }
    Ok(())
}

/// One line-protocol connection for `wu-uct top` (no retry machinery —
/// the dashboard just reconnects on the next tick).
struct TopClient {
    writer: std::net::TcpStream,
    reader: std::io::BufReader<std::net::TcpStream>,
}

impl TopClient {
    fn connect(addr: &str) -> Result<TopClient> {
        let stream = std::net::TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(TopClient { writer, reader: std::io::BufReader::new(stream) })
    }

    /// One request/reply round trip. The reply may still be `ok: false`
    /// (e.g. inspecting a session that just closed) — callers decide.
    fn call(&mut self, line: &str) -> Result<wu_uct::service::json::Json> {
        use std::io::{BufRead as _, Write as _};
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            bail!("connection closed");
        }
        Ok(wu_uct::service::json::Json::parse(reply.trim())?)
    }
}

/// Build one dashboard frame: fleet aggregate, per-shard rows when the
/// `metrics` reply carries them, and one session's `inspect` summary
/// when `--session` names one.
fn top_frame(
    client: &mut TopClient,
    addr: &str,
    tick: usize,
    session: u64,
    topk: usize,
) -> Result<Vec<String>> {
    use wu_uct::service::json::Json;
    use wu_uct::service::proto::{metrics_from_json, summary_from_json};
    let v = client.call(r#"{"op":"metrics"}"#)?;
    if v.get("ok").and_then(|b| b.as_bool()) == Some(false) {
        bail!("metrics op failed: {}", v.get("error").and_then(|e| e.as_str()).unwrap_or("?"));
    }
    let m = metrics_from_json(&v);
    let mut lines = vec![
        format!("wu-uct top — {addr} — tick {tick}"),
        format!(
            "uptime {:.1}s | shards {} | hosts {} | sessions {} open ({} opened, {} closed, {} rejected)",
            m.uptime.as_secs_f64(),
            m.shards,
            m.hosts,
            m.sessions_open,
            m.sessions_opened,
            m.sessions_closed,
            m.sessions_rejected,
        ),
        format!(
            "thinks {} ({:.1}/s) | sims {} ({:.0}/s) | ΣO {} | best flips {} | journal dropped {}",
            m.thinks, m.thinks_per_sec, m.sims, m.sims_per_sec, m.unobserved, m.best_flips, m.journal_dropped,
        ),
        format!(
            "held {} (hwm {}, shed {}) | think ms p50 {:.1} p90 {:.1} p99 {:.1} | occ exp {:.0}% sim {:.0}%",
            m.held_replies,
            m.held_replies_hwm,
            m.held_replies_shed,
            m.think_ms_p50,
            m.think_ms_p90,
            m.think_ms_p99,
            m.exp_occupancy * 100.0,
            m.sim_occupancy * 100.0,
        ),
    ];
    if let Some(Json::Arr(shards)) = v.get("per_shard") {
        lines.push(format!(
            "{:>5} {:>5} {:>8} {:>9} {:>5}",
            "shard", "open", "sim-occ", "pend-sim", "held"
        ));
        for (i, s) in shards.iter().enumerate() {
            let num = |k: &str| s.get(k).and_then(|x| x.as_u64()).unwrap_or(0);
            let occ = s.get("sim_occupancy").and_then(|x| x.as_f64()).unwrap_or(0.0);
            lines.push(format!(
                "{:>5} {:>5} {:>7.0}% {:>9} {:>5}",
                i,
                num("sessions_open"),
                occ * 100.0,
                num("pending_simulations"),
                num("held_replies"),
            ));
        }
    }
    if session != 0 {
        let v = client.call(&format!(r#"{{"op":"inspect","session":{session},"topk":{topk}}}"#))?;
        if v.get("ok").and_then(|b| b.as_bool()) == Some(true) {
            let s = summary_from_json(&v)?;
            lines.push(format!(
                "session {}: tree {} depth {} ΣO {} best a{} flips {} entropy {:.2}{}",
                s.session,
                s.tree_size,
                s.max_depth,
                s.unobserved,
                s.best_action,
                s.best_flips,
                s.root_entropy,
                if s.thinking { " (thinking)" } else { "" },
            ));
            for a in &s.top {
                let score = if a.score.is_finite() {
                    format!("{:.3}", a.score)
                } else {
                    "+inf".to_string() // unvisited: null on the wire
                };
                lines.push(format!(
                    "  a{:<4} N {:>6} O {:>4} Q {:>8.3} score {score}",
                    a.action, a.n, a.o, a.q,
                ));
            }
        } else {
            let err = v.get("error").and_then(|e| e.as_str()).unwrap_or("?");
            lines.push(format!("session {session}: {err}"));
        }
    }
    Ok(lines)
}

/// Redraw only the lines that changed since the previous frame: move
/// the cursor back to the frame top, rewrite dirty lines in place, step
/// over clean ones. Keeps a 1 Hz dashboard flicker-free without any
/// terminfo machinery.
fn draw_frame(prev: &mut Vec<String>, next: Vec<String>) {
    use std::io::Write as _;
    let out = std::io::stdout();
    let mut w = out.lock();
    if !prev.is_empty() {
        let _ = write!(w, "\x1b[{}A", prev.len());
    }
    for (i, line) in next.iter().enumerate() {
        if prev.get(i) == Some(line) {
            let _ = write!(w, "\x1b[1B"); // unchanged: step over it
        } else {
            let _ = writeln!(w, "\r\x1b[2K{line}");
        }
    }
    // A shrinking frame leaves stale lines below: blank them, then park
    // the cursor right after the new frame's last line.
    if prev.len() > next.len() {
        for _ in next.len()..prev.len() {
            let _ = writeln!(w, "\r\x1b[2K");
        }
        let _ = write!(w, "\x1b[{}A", prev.len() - next.len());
    }
    let _ = w.flush();
    *prev = next;
}

/// `wu-uct top`: poll a serve/router address and diff-render the frame
/// until killed (or for `--ticks` refreshes when bounded, e.g. in CI).
fn run_top(addr: &str, ticks: usize, interval_ms: u64, session: u64, topk: usize) -> Result<()> {
    let mut prev: Vec<String> = Vec::new();
    let mut client: Option<TopClient> = None;
    let mut tick = 0usize;
    loop {
        tick += 1;
        let frame = (|| -> Result<Vec<String>> {
            if client.is_none() {
                client = Some(TopClient::connect(addr)?);
            }
            top_frame(client.as_mut().unwrap(), addr, tick, session, topk)
        })();
        let frame = match frame {
            Ok(lines) => lines,
            Err(e) => {
                client = None; // reconnect on the next tick
                vec![
                    format!("wu-uct top — {addr} — tick {tick}"),
                    format!("unreachable: {e:#} (reconnecting)"),
                ]
            }
        };
        draw_frame(&mut prev, frame);
        if ticks > 0 && tick >= ticks {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
    Ok(())
}

/// `wu-uct play`: the anytime-serving demo — one local WU-UCT service,
/// one latency-class session, and a fixed per-frame time budget. Every
/// frame issues a deadline-bounded think (`think_ms` = `--interval-ms`),
/// advances the environment on the action the clock-cut search returned,
/// and redraws the stats block with the same diff-render loop `wu-uct
/// top` uses. `--ticks N` bounds the episode for headless runs (0 =
/// play until the episode terminates).
fn run_play(
    env_name: &str,
    scale: &Scale,
    exp_workers: usize,
    frame_ms: u64,
    ticks: usize,
) -> Result<()> {
    let service = ShardedService::start(ShardedConfig {
        shards: 1,
        shard: ServiceConfig {
            expansion_workers: exp_workers.max(1),
            simulation_workers: scale.workers.max(1),
            seed: scale.seed,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let h = service.handle();
    let spec = SearchSpec {
        max_simulations: scale.max_simulations,
        rollout_limit: scale.rollout_limit,
        seed: scale.seed,
        ..SearchSpec::default()
    };
    let sims_cap = spec.max_simulations;
    let sid = h.open(
        make_env(env_name, scale.seed),
        spec,
        SessionOptions {
            class: QosClass::Latency,
            env_seed: scale.seed,
            ..SessionOptions::default()
        },
    )?;
    println!(
        "wu-uct play — {env_name}: {frame_ms}ms of thinking per frame \
         (cap {sims_cap} sims), best-so-far action at the deadline"
    );
    let mut prev: Vec<String> = Vec::new();
    let mut step = 0usize;
    let mut ret = 0.0f64;
    loop {
        step += 1;
        let t = h.think_deadline(sid, 0, frame_ms, 0)?;
        let adv = h.advance(sid, t.action)?;
        ret += adv.reward;
        // `cut` = the deadline expired mid-search and in-flight work was
        // folded; `hit` = the sims cap drained before the clock ran out.
        let cut = if t.cutoff == Some(true) { "cut" } else { "hit" };
        let frame = vec![
            format!(
                "step {step:>4} | action a{} | reward {:+.1} | return {:+.1}",
                t.action, adv.reward, ret
            ),
            format!(
                "sims {:>6} ({cut}) | think {:>7.1}ms | tree {:>6} | ΣO=0 {} | reused {}",
                t.sims,
                t.elapsed_ms,
                t.tree_size,
                if t.quiescent { "yes" } else { "NO" },
                if adv.reused { "yes" } else { "no" },
            ),
        ];
        draw_frame(&mut prev, frame);
        if adv.done {
            println!("episode done: return {ret:+.1} over {step} step(s)");
            break;
        }
        if ticks > 0 && step >= ticks {
            println!("stopping after --ticks {ticks}: return {ret:+.1} so far");
            break;
        }
    }
    let c = h.close(sid)?;
    anyhow::ensure!(c.unobserved == 0, "session closed with ΣO = {}", c.unobserved);
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv.iter().map(|s| s.as_str()), &specs())?;
    let command = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.flag("help") || command == "help" {
        println!(
            "{}",
            usage("wu-uct", "WU-UCT parallel MCTS (ICLR 2020) reproduction", &specs())
        );
        println!("commands: search, play, serve, shard-host, flight, top, atari-table1,");
        println!("          atari-fig5, treep-ablation, sweep-speedup, breakdown, passrate,");
        println!("          policy-eval");
        return Ok(());
    }
    let scale = scale_from(&args)?;
    let out = args.str("out")?.to_string();

    match command {
        "search" => {
            let env = make_env(args.str("env")?, scale.seed);
            let spec = SearchSpec {
                max_simulations: scale.max_simulations,
                rollout_limit: scale.rollout_limit,
                seed: scale.seed,
                ..SearchSpec::default()
            };
            let mut search = by_name(args.str("algo")?, spec, scale.workers)?;
            let r = search.search(env.as_ref());
            println!(
                "{}: best action {} (value {:.3}) after {} sims in {:?}; tree {} nodes",
                search.name(),
                r.best_action,
                r.root_value,
                r.simulations,
                r.elapsed,
                r.tree_size
            );
        }
        "play" => {
            run_play(
                args.str("env")?,
                &scale,
                args.usize("exp-workers")?.max(1),
                args.u64("interval-ms")?.max(10),
                args.usize("ticks")?,
            )?;
        }
        "serve" | "shard-host" => {
            let exp_workers = args.usize("exp-workers")?.max(1);
            let sim_workers = args.usize("workers")?.max(1);
            let shards = args.usize_at_least("shards", 1)?;
            let max_sessions = args.usize("max-sessions")?;
            let max_conns = args.usize("max-conns")?;
            let data_dir = args.str("data-dir")?.to_string();
            let snapshot_every = args.u32("snapshot-every")?.max(1);
            let full_every = args.u32("full-every")?.max(1);
            let rebalance_skew = args.f64("rebalance")?;
            let hosts_arg = args.str("hosts")?.to_string();
            let replicate = args.str("replicate")?.to_string();
            let max_held = args.usize("max-held")?;
            let journal_cap = args.usize("journal-cap")?.max(1);
            let flight_dir = args.str("flight-dir")?.to_string();
            let join_router = args.str("join")?.to_string();
            if command == "serve" && !hosts_arg.is_empty() {
                // Router tier: no local shards, no local sessions — just
                // placement + proxying over the shard-host fleet.
                let hosts: Vec<String> = hosts_arg
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                let router = wu_uct::service::Router::start(wu_uct::service::RouterConfig {
                    rebalance: (rebalance_skew > 0.0).then(|| wu_uct::service::RebalanceConfig {
                        max_skew: rebalance_skew.max(1.0),
                        ..wu_uct::service::RebalanceConfig::default()
                    }),
                    suspect_after_ms: args.u64("suspect-after-ms")?.max(1),
                    lease_ttl_ms: args.u64("lease-ttl-ms")?.max(1),
                    ..wu_uct::service::RouterConfig::new(hosts.clone())
                })?;
                let server = TcpServer::bind_with_limit(
                    router.handle(),
                    args.str("addr")?,
                    (max_conns > 0).then_some(max_conns),
                )?;
                if max_conns > 0 {
                    println!("connection cap: {max_conns} concurrent, one busy line beyond");
                }
                println!(
                    "wu-uct serve (router): listening on {}, routing over {} shard host(s): {}",
                    server.local_addr(),
                    router.hosts(),
                    hosts.join(", "),
                );
                let stats_addr = args.str("stats-addr")?.to_string();
                let _stats = if stats_addr.is_empty() {
                    None
                } else {
                    let stats = StatsServer::bind(router.handle(), &stats_addr)?;
                    println!("stats: Prometheus text scrape on http://{}/metrics", stats.local_addr());
                    Some(stats)
                };
                if rebalance_skew > 0.0 {
                    println!(
                        "auto-rebalance: moving sessions across hosts above {rebalance_skew}x mean occupancy"
                    );
                }
                println!("protocol: one JSON object per line; ops: open, think, advance, best, close, migrate, export, import, install, health, metrics, trace, inspect, join, heartbeat, drain, ping");
                server.join(); // foreground until killed
                return Ok(());
            }
            if command == "shard-host" && !hosts_arg.is_empty() {
                bail!("--hosts belongs to the router (`serve`); a shard-host hosts sessions itself");
            }
            let service = ShardedService::start_durable(ShardedConfig {
                shards,
                shard: ServiceConfig {
                    expansion_workers: exp_workers,
                    simulation_workers: sim_workers,
                    seed: scale.seed,
                    max_held: (max_held > 0).then_some(max_held),
                    journal_cap,
                    ..ServiceConfig::default()
                },
                max_sessions_per_shard: (max_sessions > 0).then_some(max_sessions),
                steal: !args.flag("no-steal"),
                data_dir: (!data_dir.is_empty()).then(|| data_dir.clone().into()),
                snapshot_every,
                full_every,
                rebalance: (rebalance_skew > 0.0).then(|| wu_uct::service::RebalanceConfig {
                    max_skew: rebalance_skew.max(1.0),
                    ..wu_uct::service::RebalanceConfig::default()
                }),
                replicate: (!replicate.is_empty()).then(|| replicate.clone()),
                repl_ack: args.flag("repl-ack"),
                flight_dir: (!flight_dir.is_empty()).then(|| flight_dir.clone().into()),
                ..ShardedConfig::default()
            })?;
            let server = TcpServer::bind_with_limit(
                service.handle(),
                args.str("addr")?,
                (max_conns > 0).then_some(max_conns),
            )?;
            println!(
                "wu-uct {command}: listening on {} ({shards} shard(s), each {exp_workers} expansion / {sim_workers} simulation workers)",
                server.local_addr(),
            );
            if max_conns > 0 {
                println!("connection cap: {max_conns} concurrent, one busy line beyond");
            }
            let stats_addr = args.str("stats-addr")?.to_string();
            let _stats = if stats_addr.is_empty() {
                None
            } else {
                let stats = StatsServer::bind(service.handle(), &stats_addr)?;
                println!("stats: Prometheus text scrape on http://{}/metrics", stats.local_addr());
                Some(stats)
            };
            if command == "shard-host" {
                println!(
                    "shard host: speaks the cross-process ops (export, import, install, health) \
                     for a `wu-uct serve --hosts ...` router tier"
                );
            }
            if !replicate.is_empty() {
                println!(
                    "standby replication: streaming shard WALs to {replicate}{}",
                    if args.flag("repl-ack") {
                        " (repl-ack: replies held until the standby acks)"
                    } else {
                        " (async, bounded lag)"
                    }
                );
            }
            if max_held > 0 {
                println!("held-reply cap: {max_held} parked replies/shard, then forced flush");
            }
            if !flight_dir.is_empty() {
                println!(
                    "flight recorder: journal spills to {flight_dir}/shard-*/ (replay \
                     post-mortem with `wu-uct flight --flight-dir {flight_dir}`)"
                );
            }
            // Dynamic membership: register with the router and keep
            // heartbeating; `known:false` (router restarted) re-joins.
            let _membership = if !join_router.is_empty() {
                let advertise = {
                    let a = args.str("advertise")?.to_string();
                    if a.is_empty() { server.local_addr().to_string() } else { a }
                };
                let standby = (!replicate.is_empty()).then(|| replicate.clone());
                let router = wu_uct::service::HostClient::new(join_router.clone());
                match router.join(&advertise, standby.as_deref()) {
                    Ok(epoch) => println!(
                        "membership: joined router {join_router} as {advertise} (epoch {epoch})"
                    ),
                    Err(e) => println!(
                        "membership: router {join_router} not reachable yet ({e:#}); retrying"
                    ),
                }
                Some(std::thread::spawn(move || loop {
                    match router.heartbeat(&advertise) {
                        Ok(true) => {}
                        // Unknown (router restart) or unreachable: re-join.
                        Ok(false) | Err(_) => {
                            let _ = router.join(&advertise, standby.as_deref());
                        }
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1000));
                }))
            } else {
                None
            };
            if max_sessions > 0 {
                println!("admission control: {max_sessions} sessions/shard, busy replies beyond");
            }
            if !data_dir.is_empty() {
                let recovered = service.handle().metrics()?.sessions_recovered;
                println!(
                    "durable sessions: wal under {data_dir}/shard-*/, snapshot every \
                     {snapshot_every} think(s) (full image every {full_every} snapshot(s), \
                     deltas between; group-commit fsync batching), {recovered} session(s) \
                     recovered"
                );
            }
            if rebalance_skew > 0.0 {
                println!("auto-rebalance: moving sessions above {rebalance_skew}x mean occupancy");
            }
            println!("protocol: one JSON object per line; ops: open, think, advance, best, close, migrate, export, import, install, health, metrics, trace, inspect, replicate, repl_status, promote, ping");
            server.join(); // foreground until killed
        }
        "flight" => {
            let dir = args.str("flight-dir")?.to_string();
            if dir.is_empty() {
                bail!("flight: --flight-dir is required (the dead process's spill directory)");
            }
            run_flight(&dir, args.u64("session")?)?;
        }
        "top" => {
            run_top(
                args.str("addr")?,
                args.usize("ticks")?,
                args.u64("interval-ms")?.max(50),
                args.u64("session")?,
                args.usize("topk")?.max(1),
            )?;
        }
        "atari-table1" => {
            let games = games_from(&args, &atari::GAMES);
            let refs: Vec<&str> = games.iter().map(|s| s.as_str()).collect();
            let (table, data) = experiments::table1::run(&refs, &scale);
            emit(&table, &out)?;
            if args.flag("relative") {
                let (rel, _) = experiments::fig10::relative_performance(&data);
                print!("{}", rel.render());
            }
        }
        "atari-fig5" => {
            let games = games_from(&args, &atari::FIG5_GAMES);
            let refs: Vec<&str> = games.iter().map(|s| s.as_str()).collect();
            let table = experiments::fig5::run(&refs, &scale);
            emit(&table, &out)?;
        }
        "treep-ablation" => {
            let games = games_from(&args, &atari::TABLE5_GAMES);
            let refs: Vec<&str> = games.iter().map(|s| s.as_str()).collect();
            let (table, _) = experiments::table5::run(&refs, &scale);
            emit(&table, &out)?;
        }
        "sweep-speedup" => {
            let repeats = args.usize("repeats")?.max(1);
            if args.flag("grid") {
                let (table, _) = experiments::table3::run(&scale, repeats);
                emit(&table, &out)?;
            } else {
                for level in [Level::level35(), Level::level58()] {
                    let table =
                        experiments::fig4::speedup_curves(&level, &[1, 4, 16], &scale, repeats);
                    emit(&table, &out)?;
                }
                let perf = experiments::fig4::performance_retention(&scale);
                emit(&perf, &out)?;
            }
        }
        "breakdown" => {
            let (table, reports) = experiments::fig2::run(&scale, 2);
            emit(&table, &out)?;
            for r in &reports {
                println!(
                    "{}: simulation-worker occupancy {:.1}%",
                    r.workload,
                    r.sim_occupancy * 100.0
                );
            }
        }
        "passrate" => {
            let cfg = if args.str("scale")? == "paper" {
                SystemConfig::default()
            } else {
                SystemConfig::quick()
            };
            let (t2, f8, report) = experiments::table2_fig8::run(&cfg)?;
            emit(&t2, "")?;
            emit(&f8, &out)?;
            println!(
                "MAE {:.1}% over {} levels; {:.0}% under 20% error",
                report.mae * 100.0,
                report.errors.len(),
                report.frac_under_20 * 100.0
            );
        }
        "policy-eval" => {
            // Table 4 analogue: the rollout-policy floor vs the UCT ceiling.
            let games = games_from(&args, &atari::GAMES);
            let refs: Vec<&str> = games.iter().map(|s| s.as_str()).collect();
            let (_, data) = experiments::table1::run(&refs, &scale);
            let mut t4 = wu_uct::util::table::Table::new(
                "Table 4 — rollout-policy floor vs sequential UCT ceiling",
                &["Environment", "Policy only", "UCT"],
            );
            for (g, game) in data.games.iter().enumerate() {
                t4.row(&[
                    game.clone(),
                    format!("{:.1}", wu_uct::util::stats::mean(&data.rewards[g][4])),
                    format!("{:.1}", wu_uct::util::stats::mean(&data.rewards[g][5])),
                ]);
            }
            emit(&t4, &out)?;
        }
        other => bail!("unknown command {other:?}; try `wu-uct help`"),
    }
    Ok(())
}
