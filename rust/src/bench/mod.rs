//! Micro-benchmark harness (criterion is not in the offline crate cache).
//!
//! Used by every `cargo bench` target (`harness = false` in Cargo.toml):
//! warmup, timed iterations, mean/σ/min, and a one-line report compatible
//! with quick eyeballing and CSV capture. Kept deliberately simple — the
//! paper-reproduction benches measure *seconds-scale* end-to-end runs
//! where criterion's statistical machinery would add nothing.

use std::time::{Duration, Instant};

use crate::util::stats::{mean, std_dev};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} {:>10.3?} ± {:>9.3?} (min {:>10.3?}, n={})",
            self.name, self.mean, self.std, self.min, self.iters
        )
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean(&samples)),
        std: Duration::from_secs_f64(std_dev(&samples)),
        min: Duration::from_secs_f64(samples.iter().cloned().fold(f64::MAX, f64::min)),
    };
    println!("{}", result.report());
    result
}

/// Time a single run of `f` (for seconds-scale end-to-end benches where
/// one measurement is the honest thing to report).
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    let d = t.elapsed();
    println!("bench {name:<44} {d:>10.3?} (single run)");
    (out, d)
}

/// Scale knob shared by the bench binaries: `WU_UCT_BENCH_SCALE=paper`
/// runs paper-scale workloads; anything else (default) runs laptop scale.
pub fn paper_scale() -> bool {
    std::env::var("WU_UCT_BENCH_SCALE").map(|v| v == "paper").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0;
        let r = bench("counter", 2, 5, || count += 1);
        assert_eq!(count, 7); // 2 warmup + 5 measured
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, d) = bench_once("forty-two", || 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn report_contains_name() {
        let r = bench("named-thing", 0, 1, || ());
        assert!(r.report().contains("named-thing"));
    }

    #[test]
    fn default_scale_is_laptop() {
        // Unless explicitly set in the environment.
        if std::env::var("WU_UCT_BENCH_SCALE").is_err() {
            assert!(!paper_scale());
        }
    }
}
