//! Per-session search-health summaries — the `inspect` op's payload.
//!
//! WU-UCT's contribution is a set of tree statistics (the unobserved
//! counts `O_s` of Eqs. 4–6); this module surfaces them without paying
//! for an image export. A [`SearchSummary`] is computed on the owning
//! scheduler thread from the live tree: tree size and `max_depth` are
//! O(1) reads (the arena maintains both), `ΣO` comes from the driver's
//! running counter (maintained by the Eq. 5/6 path walks, pinned to
//! [`Tree::total_unobserved`] by the property suite), and the top-k
//! root actions plus the visit-count entropy cost O(root children).
//! Nothing here walks the whole tree or touches an env snapshot.

use crate::tree::{policy, Tree};

/// One root action's WU-UCT statistics: the observed visits `N`, the
/// in-flight unobserved count `O`, the mean value `Q`, and the modified
/// UCT decomposition (Eq. 4) — `score = q + explore` where the
/// exploration bonus uses the `N + O` totals. Unvisited actions
/// (`n + o == 0`) score `+inf`; the wire codec carries that as `null`.
#[derive(Debug, Clone, PartialEq)]
pub struct ActionStat {
    pub action: usize,
    pub n: u32,
    pub o: u32,
    pub q: f64,
    /// Exploration bonus `β √(2 ln(N_root+O_root) / (N+O))`.
    pub explore: f64,
    /// Full modified-UCT score `q + explore`.
    pub score: f64,
}

/// Compact health summary of one session's search, computed at think
/// boundaries (and safely mid-think: the scheduler thread owns the
/// tree, so a summary is a consistent snapshot between ticks).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSummary {
    pub session: u64,
    /// Total nodes in the arena.
    pub tree_size: u64,
    /// Depth of the deepest node below the current root.
    pub max_depth: u32,
    /// `ΣO` over the whole tree — unobserved samples in flight right
    /// now. Exactly 0 at every think boundary (the paper's invariant).
    pub unobserved: u64,
    /// Whether a think is currently in flight.
    pub thinking: bool,
    /// Root totals `N + O`.
    pub root_visits: u64,
    /// Mean value at the root.
    pub root_value: f64,
    /// Shannon entropy (nats) of the root children's `(N+O)` visit
    /// distribution: 0 = all mass on one action, `ln(children)` =
    /// uniform. A healthy search starts high and concentrates.
    pub root_entropy: f64,
    /// The action the search currently recommends.
    pub best_action: usize,
    /// How many times the recommendation flipped across completed
    /// thinks — a cheap convergence signal (a flapping best action means
    /// the budget is too small for the position).
    pub best_flips: u64,
    /// Top-k root actions by `N + O`, descending (ties by action id).
    pub top: Vec<ActionStat>,
}

impl SearchSummary {
    /// Build a summary from the live tree. `unobserved` is the driver's
    /// running `ΣO` counter; `beta` is the session spec's exploration
    /// constant (the score terms must match what selection computes).
    ///
    /// Cost: O(root children + top-k), never a tree walk or an export.
    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        session: u64,
        tree: &Tree,
        beta: f64,
        unobserved: u64,
        thinking: bool,
        best_flips: u64,
        topk: usize,
    ) -> SearchSummary {
        let root = tree.node(Tree::ROOT);
        let root_total = root.total_visits();
        let rows = tree.root_child_full_stats();
        // Visit-count entropy over the children's N+O mass.
        let mass: u64 = rows.iter().map(|&(_, n, o, _)| (n + o) as u64).sum();
        let root_entropy = if mass == 0 {
            0.0
        } else {
            rows.iter()
                .filter(|&&(_, n, o, _)| n + o > 0)
                .map(|&(_, n, o, _)| {
                    let p = (n + o) as f64 / mass as f64;
                    -p * p.ln()
                })
                .sum()
        };
        let mut top: Vec<ActionStat> = rows
            .iter()
            .map(|&(action, n, o, q)| {
                let score = policy::ucb_score(q, root_total, n + o, beta);
                ActionStat { action, n, o, q, explore: score - q, score }
            })
            .collect();
        top.sort_unstable_by(|a, b| {
            let ta = a.n as u64 + a.o as u64;
            let tb = b.n as u64 + b.o as u64;
            tb.cmp(&ta).then(a.action.cmp(&b.action))
        });
        top.truncate(topk);
        SearchSummary {
            session,
            tree_size: tree.len() as u64,
            max_depth: tree.max_depth(),
            unobserved,
            thinking,
            root_visits: root_total as u64,
            root_value: root.v,
            root_entropy,
            best_action: tree.best_root_action().unwrap_or(0),
            best_flips,
            top,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn searched_tree() -> Tree {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        let b = t.add_child(Tree::ROOT, 1);
        let c = t.add_child(Tree::ROOT, 2);
        let d = t.add_child(a, 4);
        t.node_mut(a).n = 6;
        t.node_mut(a).v = 0.5;
        t.node_mut(b).n = 3;
        t.node_mut(b).v = 0.2;
        t.node_mut(c).o = 2; // in flight
        t.node_mut(d).n = 2;
        t.node_mut(Tree::ROOT).n = 9;
        t.node_mut(Tree::ROOT).o = 2;
        t.node_mut(Tree::ROOT).v = 0.4;
        t
    }

    #[test]
    fn summary_reads_root_statistics_without_a_tree_walk() {
        let t = searched_tree();
        let s = SearchSummary::compute(7, &t, 1.0, 2, true, 3, 2);
        assert_eq!(s.session, 7);
        assert_eq!(s.tree_size, 5);
        assert_eq!(s.max_depth, 2);
        assert_eq!(s.unobserved, 2);
        assert!(s.thinking);
        assert_eq!(s.root_visits, 11);
        assert_eq!(s.best_flips, 3);
        assert_eq!(s.best_action, 0, "action 0 has the most observed visits");
        // Top-2 by N+O: action 0 (6), action 1 (3).
        assert_eq!(s.top.len(), 2);
        assert_eq!(s.top[0].action, 0);
        assert_eq!((s.top[0].n, s.top[0].o), (6, 0));
        assert_eq!(s.top[1].action, 1);
        // Score decomposition matches the selection policy exactly.
        let want = policy::ucb_score(0.5, 11, 6, 1.0);
        assert!((s.top[0].score - want).abs() < 1e-12);
        assert!((s.top[0].q + s.top[0].explore - s.top[0].score).abs() < 1e-12);
    }

    #[test]
    fn entropy_is_zero_when_concentrated_and_ln_k_when_uniform() {
        let mut t = Tree::new();
        let a = t.add_child(Tree::ROOT, 0);
        t.add_child(Tree::ROOT, 1);
        t.node_mut(a).n = 10;
        t.node_mut(Tree::ROOT).n = 10;
        let s = SearchSummary::compute(1, &t, 1.0, 0, false, 0, 8);
        assert_eq!(s.root_entropy, 0.0, "all mass on one action");

        let mut u = Tree::new();
        for action in 0..4 {
            let id = u.add_child(Tree::ROOT, action);
            u.node_mut(id).n = 5;
        }
        u.node_mut(Tree::ROOT).n = 20;
        let s = SearchSummary::compute(1, &u, 1.0, 0, false, 0, 8);
        assert!((s.root_entropy - 4.0f64.ln()).abs() < 1e-12, "uniform over 4 arms");
    }

    #[test]
    fn unvisited_actions_score_infinity_and_fresh_trees_summarize() {
        let mut t = Tree::new();
        t.add_child(Tree::ROOT, 0);
        let s = SearchSummary::compute(1, &t, 1.0, 0, false, 0, 4);
        assert_eq!(s.root_visits, 0);
        assert_eq!(s.root_entropy, 0.0);
        assert_eq!(s.top.len(), 1);
        assert!(s.top[0].score.is_infinite());
        let bare = SearchSummary::compute(2, &Tree::new(), 1.0, 0, false, 0, 4);
        assert_eq!(bare.tree_size, 1);
        assert!(bare.top.is_empty());
    }

    #[test]
    fn topk_orders_by_total_visits_including_inflight_o() {
        let t = searched_tree();
        let s = SearchSummary::compute(7, &t, 1.0, 2, true, 0, 3);
        // action 2 has N=0 but O=2 — in-flight mass still ranks it above
        // nothing, below actions 0 (6) and 1 (3).
        assert_eq!(
            s.top.iter().map(|r| r.action).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!((s.top[2].n, s.top[2].o), (0, 2));
    }
}
