//! Crash-surviving flight recorder: the journal ring, spilled to disk.
//!
//! The in-memory [`Journal`](crate::obs::Journal) evaporates with the
//! process — exactly when a timeline is most needed. A
//! [`FlightRecorder`] tees every journal event into checksummed,
//! rotated segment files (`flight-00000001.log`, …) under a per-shard
//! directory, using the WAL's framing and torn-tail discipline
//! (`store/wal.rs`): 10-byte `magic | version` header, then frames of
//! `length (4 LE) | FNV-1a-64 checksum (8 LE) | body`. Three deliberate
//! differences from the WAL:
//!
//! * **no fsync on the hot path** — events hit the page cache only. A
//!   SIGKILL (the case post-mortems care about) loses nothing because
//!   the kernel owns the cache; only a machine crash loses the unsynced
//!   tail, and a flight recorder is diagnostics, not durability;
//! * **bounded retention** — segments rotate at a byte budget and the
//!   oldest are deleted past a segment cap, so the recorder can run
//!   forever without eating the disk;
//! * **failure never poisons serving** — a write error marks the
//!   recorder dead (with one stderr line) and every later
//!   [`FlightRecorder::record`] is a no-op. Losing diagnostics must not
//!   take down search.
//!
//! Every boot starts a fresh segment (nothing appends after a possibly
//! torn tail). Readers tolerate a torn final frame in the *last*
//! segment only; torn data anywhere else is a hard typed
//! [`Error`](crate::store::Error) — the same ladder as the WAL
//! (BadMagic / UnsupportedVersion / ChecksumMismatch / Truncated).
//! [`replay_flight`] reconstructs one directory's event stream;
//! [`replay_flight_tree`] merges a whole `--flight-dir` of per-shard
//! subdirectories into a single `at_us`-ordered timeline — the
//! `wu-uct flight` subcommand's engine.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::obs::journal::{Event, EventKind};
use crate::store::{checksum, Error};

const FLIGHT_MAGIC: [u8; 8] = *b"WUCTFLT1";
const FLIGHT_VERSION: u16 = 1;
const SEGMENT_HEADER: usize = FLIGHT_MAGIC.len() + 2;
const FRAME_HEADER: usize = 4 + 8;
/// Encoded event body: kind tag (1) + five u64 fields.
const EVENT_BYTES: usize = 1 + 5 * 8;

/// Retention knobs for one shard's flight log.
#[derive(Debug, Clone)]
pub struct FlightConfig {
    /// Segment directory (created if absent).
    pub dir: PathBuf,
    /// Rotate to a new segment once the live one exceeds this size.
    pub max_segment_bytes: u64,
    /// Keep at most this many segments; the oldest are deleted at
    /// rotation (≥ 2 so rotation never deletes the live segment).
    pub max_segments: usize,
}

impl FlightConfig {
    pub fn new(dir: impl Into<PathBuf>) -> FlightConfig {
        FlightConfig { dir: dir.into(), max_segment_bytes: 4 << 20, max_segments: 8 }
    }
}

/// Append handle over one shard's flight-log directory.
pub struct FlightRecorder {
    cfg: FlightConfig,
    file: File,
    seg_index: u64,
    seg_bytes: u64,
    events: u64,
    dead: bool,
}

impl FlightRecorder {
    /// Open (creating the directory if needed) and start a fresh
    /// segment after any existing ones — the recorder never appends to
    /// a previous boot's possibly-torn tail.
    pub fn open(cfg: FlightConfig) -> Result<FlightRecorder, Error> {
        fs::create_dir_all(&cfg.dir)?;
        let seg_index = list_flight_segments(&cfg.dir)?
            .last()
            .map(|&(i, _)| i + 1)
            .unwrap_or(1);
        let file = start_flight_segment(&cfg.dir, seg_index)?;
        Ok(FlightRecorder {
            cfg,
            file,
            seg_index,
            seg_bytes: SEGMENT_HEADER as u64,
            events: 0,
            dead: false,
        })
    }

    /// Append one event. Never fails the caller: a write error prints
    /// one diagnostic and permanently disables the recorder.
    pub fn record(&mut self, event: &Event) {
        if self.dead {
            return;
        }
        if let Err(e) = self.write_event(event) {
            eprintln!("flight recorder disabled ({}): {e}", self.cfg.dir.display());
            self.dead = true;
        }
    }

    fn write_event(&mut self, event: &Event) -> Result<(), Error> {
        let body = encode_event(event);
        let mut frame = [0u8; FRAME_HEADER + EVENT_BYTES];
        frame[..4].copy_from_slice(&(EVENT_BYTES as u32).to_le_bytes());
        frame[4..12].copy_from_slice(&checksum(&body).to_le_bytes());
        frame[12..].copy_from_slice(&body);
        self.file.write_all(&frame)?;
        self.seg_bytes += frame.len() as u64;
        self.events += 1;
        if self.seg_bytes >= self.cfg.max_segment_bytes.max(1) {
            self.rotate()?;
        }
        Ok(())
    }

    fn rotate(&mut self) -> Result<(), Error> {
        self.seg_index += 1;
        self.file = start_flight_segment(&self.cfg.dir, self.seg_index)?;
        self.seg_bytes = SEGMENT_HEADER as u64;
        // Retention: delete the oldest segments beyond the cap.
        let segments = list_flight_segments(&self.cfg.dir)?;
        let keep = self.cfg.max_segments.max(2);
        if segments.len() > keep {
            for (_, path) in &segments[..segments.len() - keep] {
                fs::remove_file(path)?;
            }
        }
        Ok(())
    }

    /// Events written since open (this boot only).
    pub fn events_recorded(&self) -> u64 {
        self.events
    }

    /// A write failed and the recorder went inert.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    pub fn segment_index(&self) -> u64 {
        self.seg_index
    }
}

fn encode_event(e: &Event) -> [u8; EVENT_BYTES] {
    let kind = EventKind::all()
        .iter()
        .position(|&k| k == e.kind)
        .expect("every EventKind is in all()") as u8;
    let mut body = [0u8; EVENT_BYTES];
    body[0] = kind;
    body[1..9].copy_from_slice(&e.at_us.to_le_bytes());
    body[9..17].copy_from_slice(&e.session.to_le_bytes());
    body[17..25].copy_from_slice(&e.task.to_le_bytes());
    body[25..33].copy_from_slice(&e.trace.to_le_bytes());
    body[33..41].copy_from_slice(&e.arg.to_le_bytes());
    body
}

fn decode_event(body: &[u8]) -> Result<Event, Error> {
    if body.len() != EVENT_BYTES {
        return Err(Error::Corrupt { what: "flight event length" });
    }
    let kind = *EventKind::all()
        .get(body[0] as usize)
        .ok_or(Error::Corrupt { what: "unknown flight event kind" })?;
    let u64_at = |at: usize| u64::from_le_bytes(body[at..at + 8].try_into().expect("8 bytes"));
    Ok(Event {
        kind,
        at_us: u64_at(1),
        session: u64_at(9),
        task: u64_at(17),
        trace: u64_at(25),
        arg: u64_at(33),
    })
}

fn flight_segment_path(dir: &Path, index: u64) -> PathBuf {
    dir.join(format!("flight-{index:08}.log"))
}

/// Existing flight segments, sorted by index.
pub fn list_flight_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>, Error> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(stem) = name.strip_prefix("flight-").and_then(|s| s.strip_suffix(".log"))
        else {
            continue;
        };
        if let Ok(index) = stem.parse::<u64>() {
            out.push((index, entry.path()));
        }
    }
    out.sort_unstable_by_key(|&(i, _)| i);
    Ok(out)
}

fn start_flight_segment(dir: &Path, index: u64) -> Result<File, Error> {
    let mut file = File::create(flight_segment_path(dir, index))?;
    file.write_all(&FLIGHT_MAGIC)?;
    file.write_all(&FLIGHT_VERSION.to_le_bytes())?;
    Ok(file)
}

/// Contents of one flight segment.
pub struct FlightSegmentRead {
    pub events: Vec<Event>,
    /// Byte offset of the first incomplete frame, when the segment was
    /// cut off mid-write. `None` for a cleanly-ended segment.
    pub torn_at: Option<u64>,
}

/// Read one segment. With `tolerate_tail` (the final segment of a
/// killed process), a frame cut off mid-write — or a final frame whose
/// checksum fails at exactly end-of-file — is discarded and its offset
/// reported; otherwise truncation is a hard typed error. Checksum
/// mismatches with frames after them and future versions are always
/// hard errors (same ladder as the WAL).
pub fn read_flight_segment(path: &Path, tolerate_tail: bool) -> Result<FlightSegmentRead, Error> {
    let data = fs::read(path)?;
    if data.len() < SEGMENT_HEADER {
        if tolerate_tail {
            return Ok(FlightSegmentRead { events: Vec::new(), torn_at: Some(0) });
        }
        return Err(Error::Truncated { what: "flight segment header" });
    }
    if data[..FLIGHT_MAGIC.len()] != FLIGHT_MAGIC {
        return Err(Error::BadMagic);
    }
    let version = u16::from_le_bytes([data[8], data[9]]);
    if version > FLIGHT_VERSION {
        return Err(Error::UnsupportedVersion { found: version, supported: FLIGHT_VERSION });
    }
    let mut events = Vec::new();
    let mut pos = SEGMENT_HEADER;
    while pos < data.len() {
        if data.len() - pos < FRAME_HEADER {
            if tolerate_tail {
                return Ok(FlightSegmentRead { events, torn_at: Some(pos as u64) });
            }
            return Err(Error::Truncated { what: "flight frame header" });
        }
        let len =
            u32::from_le_bytes(data[pos..pos + 4].try_into().expect("4 bytes")) as usize;
        let stored =
            u64::from_le_bytes(data[pos + 4..pos + 12].try_into().expect("8 bytes"));
        let body_at = pos + FRAME_HEADER;
        if data.len() - body_at < len {
            if tolerate_tail {
                return Ok(FlightSegmentRead { events, torn_at: Some(pos as u64) });
            }
            return Err(Error::Truncated { what: "flight frame body" });
        }
        let body = &data[body_at..body_at + len];
        let computed = checksum(body);
        if stored != computed {
            if tolerate_tail && body_at + len == data.len() {
                return Ok(FlightSegmentRead { events, torn_at: Some(pos as u64) });
            }
            return Err(Error::ChecksumMismatch { expected: stored, found: computed });
        }
        events.push(decode_event(body)?);
        pos = body_at + len;
    }
    Ok(FlightSegmentRead { events, torn_at: None })
}

/// Everything replay learned from one flight-log directory.
#[derive(Debug, Default)]
pub struct FlightReplay {
    /// Every recovered event in write order (within a directory) or
    /// merged `at_us` order (across directories).
    pub events: Vec<Event>,
    /// Some segment ended mid-frame — the normal signature of a kill.
    pub torn_tail: bool,
    /// Segment files read.
    pub segments: usize,
}

/// Replay one directory of flight segments in index order. A torn tail
/// is tolerated only in the final segment; earlier damage is typed.
pub fn replay_flight(dir: &Path) -> Result<FlightReplay, Error> {
    let segments = list_flight_segments(dir)?;
    let mut out = FlightReplay::default();
    let last = segments.len().saturating_sub(1);
    for (i, (_, path)) in segments.iter().enumerate() {
        let read = read_flight_segment(path, i == last)?;
        if read.torn_at.is_some() {
            out.torn_tail = true;
        }
        out.events.extend(read.events);
        out.segments += 1;
    }
    Ok(out)
}

/// Replay a whole `--flight-dir`: if the directory holds segments
/// directly it reads them; otherwise every subdirectory containing
/// flight segments (the per-shard `shard-N/` layout the service
/// creates) is replayed and the streams are merged by `at_us` (stable,
/// so same-timestamp events keep their per-shard write order).
pub fn replay_flight_tree(dir: &Path) -> Result<FlightReplay, Error> {
    if !list_flight_segments(dir)?.is_empty() {
        return replay_flight(dir);
    }
    let mut subdirs: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort_unstable();
    let mut out = FlightReplay::default();
    for sub in subdirs {
        let sub_replay = replay_flight(&sub)?;
        out.torn_tail |= sub_replay.torn_tail;
        out.segments += sub_replay.segments;
        out.events.extend(sub_replay.events);
    }
    out.events.sort_by_key(|e| e.at_us);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("wuuct-flight-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn ev(at_us: u64, session: u64, kind: EventKind, arg: u64) -> Event {
        Event { at_us, session, task: 3, trace: 9, kind, arg }
    }

    #[test]
    fn event_encoding_roundtrips_every_kind() {
        for (i, &kind) in EventKind::all().iter().enumerate() {
            let e = ev(i as u64 * 17, 42, kind, i as u64);
            assert_eq!(decode_event(&encode_event(&e)).unwrap(), e);
        }
        assert!(matches!(
            decode_event(&[0u8; EVENT_BYTES - 1]),
            Err(Error::Corrupt { .. })
        ));
        let mut bad = encode_event(&ev(1, 1, EventKind::Admit, 0));
        bad[0] = 200; // no such kind
        assert!(matches!(decode_event(&bad), Err(Error::Corrupt { .. })));
    }

    #[test]
    fn record_and_replay_roundtrip() {
        let dir = temp_dir("roundtrip");
        let mut rec = FlightRecorder::open(FlightConfig::new(&dir)).unwrap();
        let events: Vec<Event> = (0..10)
            .map(|i| ev(i * 100, 7, EventKind::all()[i as usize % 5], i))
            .collect();
        for e in &events {
            rec.record(e);
        }
        assert_eq!(rec.events_recorded(), 10);
        assert!(!rec.is_dead());
        drop(rec);
        let replay = replay_flight(&dir).unwrap();
        assert_eq!(replay.events, events);
        assert!(!replay.torn_tail);
        assert_eq!(replay.segments, 1);
    }

    #[test]
    fn each_boot_starts_a_fresh_segment() {
        let dir = temp_dir("fresh-boot");
        for boot in 0..3u64 {
            let mut rec = FlightRecorder::open(FlightConfig::new(&dir)).unwrap();
            assert_eq!(rec.segment_index(), boot + 1);
            rec.record(&ev(boot, 1, EventKind::Admit, 0));
        }
        let replay = replay_flight(&dir).unwrap();
        assert_eq!(replay.segments, 3);
        assert_eq!(replay.events.len(), 3);
    }

    #[test]
    fn rotation_bounds_segment_count() {
        let dir = temp_dir("rotation");
        let mut cfg = FlightConfig::new(&dir);
        cfg.max_segment_bytes = 64; // ~1 event per segment
        cfg.max_segments = 3;
        let mut rec = FlightRecorder::open(cfg).unwrap();
        for i in 0..20 {
            rec.record(&ev(i, 1, EventKind::Select, i));
        }
        let segments = list_flight_segments(&dir).unwrap();
        assert!(segments.len() <= 3, "retention cap holds: {}", segments.len());
        // The newest events survive; replay still parses cleanly.
        let replay = replay_flight(&dir).unwrap();
        assert!(!replay.events.is_empty());
        assert_eq!(replay.events.last().unwrap().at_us, 19);
    }

    #[test]
    fn torn_tail_in_final_segment_is_tolerated_and_reported() {
        let dir = temp_dir("torn");
        let mut rec = FlightRecorder::open(FlightConfig::new(&dir)).unwrap();
        for i in 0..4 {
            rec.record(&ev(i, 2, EventKind::SimDone, i));
        }
        drop(rec);
        let path = flight_segment_path(&dir, 1);
        let len = fs::metadata(&path).unwrap().len();
        fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(len - 5)
            .unwrap();
        let replay = replay_flight(&dir).unwrap();
        assert!(replay.torn_tail);
        assert_eq!(replay.events.len(), 3, "intact prefix survives");
        // The same damage in a non-final segment is a hard error.
        assert!(read_flight_segment(&path, false).is_err());
    }

    #[test]
    fn mid_segment_corruption_is_a_typed_error() {
        let dir = temp_dir("corrupt");
        let mut rec = FlightRecorder::open(FlightConfig::new(&dir)).unwrap();
        for i in 0..4 {
            rec.record(&ev(i, 2, EventKind::Backprop, i));
        }
        drop(rec);
        let path = flight_segment_path(&dir, 1);
        let mut data = fs::read(&path).unwrap();
        // Flip a byte in the first frame's body: a later complete frame
        // follows, so this can never be mistaken for a torn tail.
        data[SEGMENT_HEADER + FRAME_HEADER + 2] ^= 0xFF;
        fs::write(&path, &data).unwrap();
        assert!(matches!(
            read_flight_segment(&path, true),
            Err(Error::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn bad_magic_and_future_version_are_refused() {
        let dir = temp_dir("magic");
        fs::create_dir_all(&dir).unwrap();
        let path = flight_segment_path(&dir, 1);
        fs::write(&path, b"NOTFLT99......").unwrap();
        assert!(matches!(read_flight_segment(&path, true), Err(Error::BadMagic)));
        let mut future = Vec::new();
        future.extend_from_slice(&FLIGHT_MAGIC);
        future.extend_from_slice(&99u16.to_le_bytes());
        fs::write(&path, &future).unwrap();
        assert!(matches!(
            read_flight_segment(&path, false),
            Err(Error::UnsupportedVersion { found: 99, .. })
        ));
    }

    #[test]
    fn tree_replay_merges_per_shard_subdirs_by_timestamp() {
        let root = temp_dir("tree");
        for (shard, base) in [(0u32, 0u64), (1, 50)] {
            let sub = root.join(format!("shard-{shard}"));
            let mut rec = FlightRecorder::open(FlightConfig::new(&sub)).unwrap();
            for i in 0..3 {
                rec.record(&ev(base + i * 100, shard as u64, EventKind::Admit, i));
            }
        }
        let replay = replay_flight_tree(&root).unwrap();
        assert_eq!(replay.segments, 2);
        let times: Vec<u64> = replay.events.iter().map(|e| e.at_us).collect();
        assert_eq!(times, vec![0, 50, 100, 150, 200, 250], "merged by at_us");
    }
}
