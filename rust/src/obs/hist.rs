//! Fixed log-scale-bucket latency histogram.
//!
//! Bucket `i` (for `i < NUM_BUCKETS - 1`) covers values in
//! `(upper(i-1), upper(i)]` milliseconds with `upper(i) = 0.01 ·
//! 10^(i/5)`; bucket 0 additionally absorbs everything `≤ 0.01 ms`
//! (including zero and garbage negatives), and the last bucket is the
//! overflow for anything above `upper(NUM_BUCKETS - 2)` = 100 s. Five
//! buckets per decade bound the relative quantile error at
//! `10^(1/5) ≈ 1.585`, and seven decades (0.01 ms .. 100 s) cover
//! everything from a sub-microsecond in-process reply to a wedged
//! fsync.
//!
//! The layout is a protocol constant: two histograms merge by plain
//! bucket addition ([`Histogram::merge`]), which is what lets
//! `ServiceMetrics::aggregate` combine shard and host distributions
//! *exactly* instead of taking the worst shard's percentile. Keep
//! `NUM_BUCKETS`/`BUCKET_RATIO` stable or version the wire format.

/// Buckets per decade; the relative resolution is `10^(1/PER_DECADE)`.
pub const PER_DECADE: usize = 5;

/// Decades covered above the first bucket (0.01 ms .. 100 s).
const DECADES: usize = 7;

/// Total buckets: bucket 0 (`≤ 0.01 ms`), `PER_DECADE · DECADES`
/// log-spaced buckets, and one overflow bucket.
pub const NUM_BUCKETS: usize = PER_DECADE * DECADES + 2;

/// Upper bound of bucket 0 in milliseconds.
const FIRST_UPPER_MS: f64 = 0.01;

/// Ratio between adjacent bucket upper bounds (`10^(1/5)`).
pub const BUCKET_RATIO: f64 = 1.584_893_192_461_113_5;

/// Upper bound (inclusive) of bucket `i` in milliseconds; the overflow
/// bucket reports `f64::INFINITY`.
pub fn bucket_upper_ms(i: usize) -> f64 {
    if i >= NUM_BUCKETS - 1 {
        f64::INFINITY
    } else {
        FIRST_UPPER_MS * 10f64.powf(i as f64 / PER_DECADE as f64)
    }
}

fn bucket_index(ms: f64) -> usize {
    if !(ms > FIRST_UPPER_MS) {
        // NaN, negatives, zero, and genuinely tiny values all land in
        // bucket 0; `!(..)` keeps NaN out of the log path.
        return 0;
    }
    let pos = (ms / FIRST_UPPER_MS).log10() * PER_DECADE as f64;
    let i = (pos.ceil().max(0.0) as usize).min(NUM_BUCKETS - 1);
    // Float guard: if rounding put us one bucket low, bump.
    if i < NUM_BUCKETS - 1 && bucket_upper_ms(i) < ms {
        i + 1
    } else {
        i
    }
}

/// A mergeable latency distribution. `record` is O(1) and allocates
/// nothing; `merge` is exact (bucket addition); `percentile_ms` walks
/// the fixed bucket array — O(buckets), independent of sample count.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: [u64; NUM_BUCKETS],
    count: u64,
    sum_ms: f64,
    min_ms: f64,
    max_ms: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: [0; NUM_BUCKETS],
            count: 0,
            sum_ms: 0.0,
            min_ms: f64::INFINITY,
            max_ms: 0.0,
        }
    }

    /// Record one sample in milliseconds.
    pub fn record(&mut self, ms: f64) {
        let ms = if ms.is_finite() && ms >= 0.0 { ms } else { 0.0 };
        self.counts[bucket_index(ms)] += 1;
        self.count += 1;
        self.sum_ms += ms;
        self.min_ms = self.min_ms.min(ms);
        self.max_ms = self.max_ms.max(ms);
    }

    /// Fold `other` into `self`. Exact: the result is identical to a
    /// histogram that recorded both sample streams directly.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_ms += other.sum_ms;
        self.min_ms = self.min_ms.min(other.min_ms);
        self.max_ms = self.max_ms.max(other.max_ms);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_ms
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_ms
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    /// Nearest-rank percentile (same rank convention as the raw-sample
    /// [`crate::service::metrics::percentile`] helper), reported as the
    /// upper bound of the bucket holding that rank, clamped to the
    /// observed maximum. The true sample at that rank lies within one
    /// bucket ratio below the returned value.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return if i == NUM_BUCKETS - 1 {
                    self.max_ms
                } else {
                    bucket_upper_ms(i).min(self.max_ms)
                };
            }
        }
        self.max_ms
    }

    /// Raw bucket counts (index aligned with [`bucket_upper_ms`]).
    pub fn bucket_counts(&self) -> &[u64; NUM_BUCKETS] {
        &self.counts
    }

    /// Sparse `(bucket index, count)` pairs for the wire — most
    /// histograms occupy a handful of the 37 buckets.
    pub fn sparse(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Rebuild from wire fields (inverse of the sparse encoding).
    /// Out-of-range bucket indices are dropped rather than panicking —
    /// the wire is untrusted.
    pub fn from_wire(
        count: u64,
        sum_ms: f64,
        min_ms: f64,
        max_ms: f64,
        sparse: &[(usize, u64)],
    ) -> Histogram {
        let mut h = Histogram::new();
        for &(i, c) in sparse {
            if i < NUM_BUCKETS {
                h.counts[i] += c;
            }
        }
        h.count = count;
        h.sum_ms = sum_ms;
        h.min_ms = if count == 0 { f64::INFINITY } else { min_ms };
        h.max_ms = max_ms;
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.min_ms(), 0.0);
        assert_eq!(h.max_ms(), 0.0);
        assert_eq!(h.percentile_ms(99.0), 0.0);
        assert!(h.sparse().is_empty());
    }

    #[test]
    fn bucket_bounds_are_monotone_and_cover_samples() {
        for i in 1..NUM_BUCKETS - 1 {
            assert!(bucket_upper_ms(i) > bucket_upper_ms(i - 1));
        }
        let mut rng = SplitMix64::new(7);
        for _ in 0..2000 {
            let ms = (rng.next_u64() % 10_000_000) as f64 / 100.0; // 0 .. 100 s
            let b = bucket_index(ms);
            assert!(ms <= bucket_upper_ms(b), "sample {ms} above bucket {b} upper");
            if b > 0 {
                assert!(ms > bucket_upper_ms(b - 1), "sample {ms} below bucket {b} lower");
            }
        }
    }

    #[test]
    fn degenerate_samples_land_in_bucket_zero() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-5.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 4);
        assert_eq!(h.bucket_counts()[0], 4);
    }

    #[test]
    fn overflow_bucket_catches_huge_samples() {
        let mut h = Histogram::new();
        h.record(1.0e9);
        assert_eq!(h.bucket_counts()[NUM_BUCKETS - 1], 1);
        assert_eq!(h.percentile_ms(50.0), 1.0e9); // overflow reports observed max
    }

    #[test]
    fn merge_equals_pooled_recording() {
        let mut rng = SplitMix64::new(42);
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut pooled = Histogram::new();
        for i in 0..5000 {
            let ms = 0.05 + (rng.next_u64() % 1_000_000) as f64 / 200.0;
            pooled.record(ms);
            if i % 3 == 0 { a.record(ms) } else { b.record(ms) }
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, pooled, "merge must be exact, not approximate");
    }

    #[test]
    fn percentile_within_one_bucket_of_true_value() {
        let mut rng = SplitMix64::new(3);
        let mut h = Histogram::new();
        let mut raw: Vec<f64> = Vec::new();
        for _ in 0..4000 {
            let ms = 0.05 + (rng.next_u64() % 500_000) as f64 / 100.0;
            h.record(ms);
            raw.push(ms);
        }
        raw.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let rank = ((p / 100.0) * (raw.len() - 1) as f64).round() as usize;
            let truth = raw[rank];
            let est = h.percentile_ms(p);
            assert!(truth <= est * (1.0 + 1e-12), "p{p}: true {truth} > estimate {est}");
            assert!(
                est <= truth * BUCKET_RATIO * (1.0 + 1e-12),
                "p{p}: estimate {est} more than one bucket above true {truth}"
            );
        }
    }

    #[test]
    fn wire_sparse_roundtrip_is_lossless() {
        let mut h = Histogram::new();
        for ms in [0.02, 0.5, 0.5, 17.0, 200.0, 90_000.0, 1.0e7] {
            h.record(ms);
        }
        let back = Histogram::from_wire(h.count(), h.sum_ms(), h.min_ms(), h.max_ms(), &h.sparse());
        assert_eq!(back, h);
        let empty = Histogram::from_wire(0, 0.0, 0.0, 0.0, &[]);
        assert_eq!(empty, Histogram::new());
        // Hostile bucket index is dropped, not a panic.
        let hostile = Histogram::from_wire(1, 1.0, 1.0, 1.0, &[(usize::MAX, 9)]);
        assert_eq!(hostile.bucket_counts().iter().sum::<u64>(), 0);
    }
}
