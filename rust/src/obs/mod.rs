//! Structured observability: mergeable latency histograms and a typed
//! per-shard event journal.
//!
//! Two primitives, both chosen for the same property — **aggregation
//! without loss on the hot path**:
//!
//! - [`Histogram`]: fixed log-scale buckets (5 per decade from 0.01 ms).
//!   Recording is O(1) with zero allocation; merging two histograms is
//!   exact bucket addition, so fleet-wide percentiles computed from the
//!   merged histogram equal the percentiles of the pooled samples to
//!   within one bucket's relative resolution (a factor of 10^(1/5) ≈
//!   1.58). This replaces the old `LatencyStats` sample vector, whose
//!   per-scrape clone+sort ran on the scheduler dispatch thread and
//!   whose cross-shard aggregate could only take the *worst* shard's
//!   percentile.
//! - [`Journal`]: a bounded ring of typed [`Event`]s recorded by the
//!   scheduler thread (single-writer, so no locking). Events carry the
//!   shard-tagged global task id, the session id, and an optional
//!   caller-supplied trace id, so one think's causal timeline — admit →
//!   select → expand/sim issue+done → backprop → reply-held → durable →
//!   reply-sent, plus WAL batch/fsync and steal/migration steps —
//!   reconstructs by filtering and sorting on `at_us`. The `trace` wire
//!   op exposes the journal; the router stitches per-host journals into
//!   one cross-host timeline by the propagated trace id.
//!
//! Timestamps are microseconds since an arbitrary per-process origin
//! (the scheduler's start instant in production, the virtual clock in
//! the testkit), which keeps deterministic tests byte-stable.
//!
//! Two further layers build on those primitives:
//!
//! - [`flight`]: a crash-surviving spill of the journal ring to
//!   checksummed, rotated segment files (the WAL's framing without its
//!   fsyncs) — `wu-uct flight` reconstructs post-mortem timelines from
//!   them after a SIGKILL.
//! - [`search`]: the `inspect` op's per-session [`SearchSummary`] —
//!   WU-UCT's own health statistics (ΣO in flight, root visit entropy,
//!   top-k modified-UCT score terms, best-action flips) computed in
//!   O(top-k + depth) from maintained counters, never an image export.

pub mod flight;
pub mod hist;
pub mod journal;
pub mod search;

pub use flight::{
    list_flight_segments, read_flight_segment, replay_flight, replay_flight_tree,
    FlightConfig, FlightRecorder, FlightReplay, FlightSegmentRead,
};
pub use hist::{bucket_upper_ms, Histogram, BUCKET_RATIO, NUM_BUCKETS};
pub use journal::{Event, EventKind, Journal};
pub use search::{ActionStat, SearchSummary};
