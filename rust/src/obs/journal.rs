//! Bounded ring journal of typed scheduler events.
//!
//! Single-writer by construction: a `Journal` is owned by its scheduler
//! thread (or the testkit's virtual-time executor) and every record
//! happens there, so there is no lock and no atomics on the hot path —
//! "lock-light" means the synchronization cost is zero because the
//! design puts all writes on one thread, and reads travel the same
//! request inbox every other scheduler query uses.
//!
//! Events are keyed three ways so a timeline reconstructs by filtering:
//! the session id, the shard-tagged global task id (for pool tasks),
//! and an optional caller-supplied trace id which the router propagates
//! across hosts. When the ring wraps, the oldest events drop and
//! `dropped()` counts them — a trace of a recent think is complete as
//! long as the ring (default 4096 events/shard) outlives the think.

use std::collections::VecDeque;

/// What happened. Names (`EventKind::name`) are the wire/scrape
/// vocabulary; keep them stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A think request was admitted for a session (`arg` = sim budget).
    Admit,
    /// The fair queue selected the session for one driver tick.
    Select,
    /// An expansion task was handed to the pool (`task` = global id).
    ExpandIssued,
    /// An expansion result was absorbed (`arg` = task latency in µs).
    ExpandDone,
    /// A simulation task was handed to the pool (`task` = global id).
    SimIssued,
    /// A simulation result was absorbed (`arg` = task latency in µs).
    SimDone,
    /// A simulation was shed to the shared steal queue (`arg` = owner shard).
    StealShed,
    /// A stolen simulation's result arrived from shard `arg`.
    StealClaim,
    /// Incomplete-visit backprop applied for an absorbed result.
    Backprop,
    /// The think finished its budget; quiescent (`arg` = sims done).
    ThinkDone,
    /// The reply was parked awaiting WAL durability (`arg` = commit seq).
    ReplyHeld,
    /// A WAL record was appended for the session (`arg` = commit seq).
    WalAppend,
    /// The group committer fsynced a batch (`arg` = durable seq).
    WalFsync,
    /// A parked reply's commit seq became durable (`arg` = commit seq).
    Durable,
    /// The reply left the scheduler (`arg` = µs held on the ticket, 0 if
    /// it was never parked).
    ReplySent,
    /// Migration: session exported (`arg` = image bytes).
    MigrateExport,
    /// Migration: session imported (`arg` = image bytes).
    MigrateImport,
    /// Migration: source forgot the session after handoff.
    MigrateForget,
    /// A snapshot was written (`arg` = snapshot bytes).
    Snapshot,
    /// A session opened (`arg` = shard index).
    SessionOpen,
    /// A session closed.
    SessionClose,
    /// A think's deadline expired mid-search: in-flight tasks were folded
    /// back to quiescence and the think finished at its truncated budget
    /// (`arg` = tasks folded).
    DeadlineCut,
}

impl EventKind {
    /// Stable wire name.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Select => "select",
            EventKind::ExpandIssued => "expand_issued",
            EventKind::ExpandDone => "expand_done",
            EventKind::SimIssued => "sim_issued",
            EventKind::SimDone => "sim_done",
            EventKind::StealShed => "steal_shed",
            EventKind::StealClaim => "steal_claim",
            EventKind::Backprop => "backprop",
            EventKind::ThinkDone => "think_done",
            EventKind::ReplyHeld => "reply_held",
            EventKind::WalAppend => "wal_append",
            EventKind::WalFsync => "wal_fsync",
            EventKind::Durable => "durable",
            EventKind::ReplySent => "reply_sent",
            EventKind::MigrateExport => "migrate_export",
            EventKind::MigrateImport => "migrate_import",
            EventKind::MigrateForget => "migrate_forget",
            EventKind::Snapshot => "snapshot",
            EventKind::SessionOpen => "session_open",
            EventKind::SessionClose => "session_close",
            EventKind::DeadlineCut => "deadline_cut",
        }
    }

    /// Inverse of [`EventKind::name`] (for decoding `trace` replies).
    pub fn from_name(name: &str) -> Option<EventKind> {
        Some(match name {
            "admit" => EventKind::Admit,
            "select" => EventKind::Select,
            "expand_issued" => EventKind::ExpandIssued,
            "expand_done" => EventKind::ExpandDone,
            "sim_issued" => EventKind::SimIssued,
            "sim_done" => EventKind::SimDone,
            "steal_shed" => EventKind::StealShed,
            "steal_claim" => EventKind::StealClaim,
            "backprop" => EventKind::Backprop,
            "think_done" => EventKind::ThinkDone,
            "reply_held" => EventKind::ReplyHeld,
            "wal_append" => EventKind::WalAppend,
            "wal_fsync" => EventKind::WalFsync,
            "durable" => EventKind::Durable,
            "reply_sent" => EventKind::ReplySent,
            "migrate_export" => EventKind::MigrateExport,
            "migrate_import" => EventKind::MigrateImport,
            "migrate_forget" => EventKind::MigrateForget,
            "snapshot" => EventKind::Snapshot,
            "session_open" => EventKind::SessionOpen,
            "session_close" => EventKind::SessionClose,
            "deadline_cut" => EventKind::DeadlineCut,
            _ => return None,
        })
    }

    /// Every kind, for exhaustive wire tests.
    pub fn all() -> &'static [EventKind] {
        &[
            EventKind::Admit,
            EventKind::Select,
            EventKind::ExpandIssued,
            EventKind::ExpandDone,
            EventKind::SimIssued,
            EventKind::SimDone,
            EventKind::StealShed,
            EventKind::StealClaim,
            EventKind::Backprop,
            EventKind::ThinkDone,
            EventKind::ReplyHeld,
            EventKind::WalAppend,
            EventKind::WalFsync,
            EventKind::Durable,
            EventKind::ReplySent,
            EventKind::MigrateExport,
            EventKind::MigrateImport,
            EventKind::MigrateForget,
            EventKind::Snapshot,
            EventKind::SessionOpen,
            EventKind::SessionClose,
            // Appended last: the flight recorder encodes kinds by their
            // position in this slice, so order is a wire format.
            EventKind::DeadlineCut,
        ]
    }
}

/// One journal entry. `at_us` is microseconds since the owning
/// scheduler's start (virtual ticks in the testkit); `task` is the
/// shard-tagged global task id or 0 for session-scoped events; `trace`
/// is the caller-supplied trace id or 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub at_us: u64,
    pub session: u64,
    pub task: u64,
    pub trace: u64,
    pub kind: EventKind,
    pub arg: u64,
}

/// Bounded ring of [`Event`]s; oldest entries drop when full.
#[derive(Debug)]
pub struct Journal {
    events: VecDeque<Event>,
    cap: usize,
    dropped: u64,
}

/// Default ring capacity per shard.
pub const DEFAULT_JOURNAL_CAP: usize = 4096;

impl Default for Journal {
    fn default() -> Journal {
        Journal::new(DEFAULT_JOURNAL_CAP)
    }
}

impl Journal {
    pub fn new(cap: usize) -> Journal {
        let cap = cap.max(1);
        Journal { events: VecDeque::with_capacity(cap), cap, dropped: 0 }
    }

    /// Append one event; drops the oldest entry past capacity.
    pub fn record(&mut self, event: Event) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(event);
    }

    /// Events recorded then evicted by the ring bound.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The newest `limit` events (oldest-first), optionally filtered to
    /// one session. Trace-tagged events always match their session
    /// filter via the session field, so a cross-shard think filtered by
    /// session id still shows its stolen-task events — those carry the
    /// home session id, not the thief's.
    pub fn query(&self, session: Option<u64>, limit: usize) -> Vec<Event> {
        let mut out: Vec<Event> = match session {
            Some(s) => self.events.iter().filter(|e| e.session == s).cloned().collect(),
            None => self.events.iter().cloned().collect(),
        };
        if out.len() > limit {
            out.drain(..out.len() - limit);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at_us: u64, session: u64, kind: EventKind) -> Event {
        Event { at_us, session, task: 0, trace: 0, kind, arg: 0 }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut j = Journal::new(3);
        for i in 0..5 {
            j.record(ev(i, 1, EventKind::Select));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.dropped(), 2);
        let got = j.query(None, 10);
        assert_eq!(got.iter().map(|e| e.at_us).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn query_filters_by_session_and_limits_to_newest() {
        let mut j = Journal::new(16);
        for i in 0..8 {
            j.record(ev(i, i % 2, EventKind::Admit));
        }
        let s1 = j.query(Some(1), 10);
        assert_eq!(s1.iter().map(|e| e.at_us).collect::<Vec<_>>(), vec![1, 3, 5, 7]);
        let newest = j.query(Some(1), 2);
        assert_eq!(newest.iter().map(|e| e.at_us).collect::<Vec<_>>(), vec![5, 7]);
    }

    #[test]
    fn kind_names_roundtrip_and_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for &k in EventKind::all() {
            assert!(seen.insert(k.name()), "duplicate wire name {}", k.name());
            assert_eq!(EventKind::from_name(k.name()), Some(k));
        }
        assert_eq!(EventKind::from_name("definitely_not_a_kind"), None);
    }
}
