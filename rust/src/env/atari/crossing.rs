//! Crossing mechanic: cross lanes of moving traffic (Freeway analogue).
//!
//! Actions: 0=up 1=down 2=stay. The chicken starts below lane 0 and scores
//! on reaching the far side, then restarts. Collisions knock it back two
//! lanes. The score cap and low variance mirror Freeway's 32±0 behaviour.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct CrossingConfig {
    pub name: &'static str,
    pub lanes: i64,
    pub lane_width: i64,
    pub cross_reward: f64,
    pub horizon: u32,
}

impl CrossingConfig {
    pub fn freeway() -> Self {
        CrossingConfig {
            name: "Freeway",
            lanes: 8,
            lane_width: 10,
            cross_reward: 1.0,
            horizon: 320,
        }
    }

    pub fn gravitar() -> Self {
        // Gravitar's sparse-reward hazardous navigation, as a harder
        // crossing: more lanes, bigger payoff, longer horizon.
        CrossingConfig {
            name: "Gravitar",
            lanes: 12,
            lane_width: 8,
            cross_reward: 250.0,
            horizon: 400,
        }
    }
}

#[derive(Debug, Clone)]
pub struct CrossingGame {
    cfg: CrossingConfig,
    rng: Pcg32,
    /// Player's lane: -1 = start side, cfg.lanes = goal side.
    player_lane: i64,
    /// One car per lane: position in [0, lane_width) and speed.
    cars: Vec<(i64, i64)>,
    step: u32,
    crossings: u32,
    score: f64,
}

impl CrossingGame {
    pub fn new(cfg: CrossingConfig, seed: u64) -> Self {
        let mut g = CrossingGame {
            cfg,
            rng: Pcg32::new(seed),
            player_lane: -1,
            cars: Vec::new(),
            step: 0,
            crossings: 0,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    /// The player occupies column 0 of each lane; a collision happens when
    /// the car in the player's lane passes position 0 on its move.
    fn car_hits_player(&self, lane: i64) -> bool {
        if !(0..self.cfg.lanes).contains(&lane) {
            return false;
        }
        let (pos, _speed) = self.cars[lane as usize];
        pos == 0
    }

    fn advance_cars(&mut self) {
        let w = self.cfg.lane_width;
        for (pos, speed) in self.cars.iter_mut() {
            *pos = (*pos + *speed).rem_euclid(w);
        }
    }
}

impl Env for CrossingGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        w.i64(self.player_lane);
        w.u32(self.cars.len() as u32);
        for &(p, v) in &self.cars {
            w.i64(p);
            w.i64(v);
        }
        w.u32(self.step);
        w.u32(self.crossings);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.player_lane = r.i64();
        let n = r.u32() as usize;
        self.cars = (0..n).map(|_| (r.i64(), r.i64())).collect();
        self.step = r.u32();
        self.crossings = r.u32();
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0xc05);
        self.player_lane = -1;
        self.cars = (0..self.cfg.lanes)
            .map(|i| {
                let pos = self.rng.below(self.cfg.lane_width as u32) as i64;
                // Alternating directions, speeds 1-2.
                let speed = (1 + (i % 2)) * if i % 2 == 0 { 1 } else { -1 };
                (pos, speed)
            })
            .collect();
        self.step = 0;
        self.crossings = 0;
        self.score = 0.0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal crossing state");
        assert!(action < 3, "crossing action {action} out of range");
        match action {
            0 => self.player_lane = (self.player_lane + 1).min(self.cfg.lanes),
            1 => self.player_lane = (self.player_lane - 1).max(-1),
            _ => {}
        }
        let mut reward = 0.0;
        if self.player_lane >= self.cfg.lanes {
            reward += self.cfg.cross_reward;
            self.crossings += 1;
            self.player_lane = -1; // restart for another crossing
        }
        self.advance_cars();
        if self.car_hits_player(self.player_lane) {
            // Knocked back two lanes.
            self.player_lane = (self.player_lane - 2).max(-1);
        }
        self.step += 1;
        self.score += reward;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        vec![0, 1, 2]
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn is_terminal(&self) -> bool {
        self.step >= self.cfg.horizon
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        let next_lane = match action {
            0 => (self.player_lane + 1).min(self.cfg.lanes),
            1 => (self.player_lane - 1).max(-1),
            _ => self.player_lane,
            // advancing is good unless the next lane's car is about to be
            // at the crossing column
        };
        let danger = if (0..self.cfg.lanes).contains(&next_lane) {
            let (pos, speed) = self.cars[next_lane as usize];
            let next_pos = (pos + speed).rem_euclid(self.cfg.lane_width);
            next_pos == 0
        } else {
            false
        };
        let base: f64 = match action {
            0 => 0.85, // forward
            2 => 0.35,
            _ => 0.1, // backward
        };
        if danger {
            (base - 0.7).max(0.0)
        } else {
            base
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        let progress = (self.player_lane + 1) as f64 / (self.cfg.lanes + 1) as f64;
        let pace = self.crossings as f64 / (self.cfg.horizon as f64 / 40.0).max(1.0);
        (0.4 * progress + 0.6 * pace.min(1.0) - 0.2).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        if out.len() < 4 {
            return;
        }
        out[0] = (self.player_lane + 1) as f32 / (self.cfg.lanes + 1) as f32;
        out[1] = self.crossings as f32 / 40.0;
        if (0..self.cfg.lanes).contains(&self.player_lane) {
            let (pos, _) = self.cars[self.player_lane as usize];
            out[2] = pos as f32 / self.cfg.lane_width as f32;
        }
        let next = self.player_lane + 1;
        if (0..self.cfg.lanes).contains(&next) {
            let (pos, _) = self.cars[next as usize];
            out[3] = pos as f32 / self.cfg.lane_width as f32;
        }
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_play_scores_crossings() {
        let mut g = CrossingGame::new(CrossingConfig::freeway(), 1);
        while !g.is_terminal() {
            g.step(0);
        }
        assert!(g.crossings > 0, "always-forward must cross at least once");
        assert!(g.score > 0.0);
    }

    #[test]
    fn crossing_resets_to_start_side() {
        let mut g = CrossingGame::new(CrossingConfig::freeway(), 2);
        let mut crossed = false;
        while !g.is_terminal() {
            let r = g.step(0);
            if r.reward > 0.0 {
                crossed = true;
                assert_eq!(g.player_lane, -1);
                break;
            }
        }
        assert!(crossed);
    }

    #[test]
    fn player_lane_bounded() {
        let mut g = CrossingGame::new(CrossingConfig::freeway(), 3);
        for i in 0..100 {
            if g.is_terminal() {
                break;
            }
            g.step([1, 1, 0][i % 3]);
            assert!(g.player_lane >= -1 && g.player_lane <= g.cfg.lanes);
        }
    }

    #[test]
    fn horizon_terminates() {
        let mut g = CrossingGame::new(CrossingConfig::gravitar(), 4);
        let mut n = 0;
        while !g.is_terminal() {
            g.step(2);
            n += 1;
        }
        assert_eq!(n, g.cfg.horizon);
    }

    #[test]
    fn snapshot_restore_replay() {
        let mut g = CrossingGame::new(CrossingConfig::freeway(), 5);
        for _ in 0..11 {
            g.step(0);
        }
        let snap = g.snapshot();
        let mut h = CrossingGame::new(CrossingConfig::freeway(), 77);
        h.restore(&snap);
        for i in 0..40 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(i % 3), h.step(i % 3));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let play = |seed| {
            let mut g = CrossingGame::new(CrossingConfig::freeway(), seed);
            while !g.is_terminal() {
                g.step(0);
            }
            (g.score, g.crossings)
        };
        assert_eq!(play(9), play(9));
    }

    #[test]
    fn freeway_score_cap_is_stable() {
        // Like the real Freeway, a sensible policy lands in a narrow score
        // band across seeds (the paper reports 32±0).
        let scores: Vec<f64> = (0..5)
            .map(|seed| {
                let mut g = CrossingGame::new(CrossingConfig::freeway(), seed);
                while !g.is_terminal() {
                    let a = (0..3)
                        .max_by(|&a, &b| {
                            g.action_heuristic(a)
                                .partial_cmp(&g.action_heuristic(b))
                                .unwrap()
                        })
                        .unwrap();
                    g.step(a);
                }
                g.score
            })
            .collect();
        let mean = scores.iter().sum::<f64>() / scores.len() as f64;
        assert!(mean > 5.0, "heuristic play should cross repeatedly: {scores:?}");
        let spread = scores
            .iter()
            .map(|s| (s - mean).abs())
            .fold(0.0f64, f64::max);
        assert!(spread <= mean, "low-variance game: {scores:?}");
    }
}
