//! Chase mechanic: a player collects pellets on a grid while ghosts pursue
//! (MsPacman / Alien / Qbert analogue).
//!
//! Actions: 0=up 1=down 2=left 3=right 4=stay. Reward: `pellet_reward` per
//! pellet, `capture_penalty` on being caught (ends the episode). Ghosts
//! move every `ghost_period` steps; with probability `ghost_smart` a ghost
//! steps toward the player, otherwise randomly — the knob that separates
//! "Alien" (aggressive) from "MsPacman" (wanderers).

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

/// Parameterization of the chase mechanic.
#[derive(Debug, Clone)]
pub struct ChaseConfig {
    pub name: &'static str,
    pub width: i64,
    pub height: i64,
    pub num_ghosts: usize,
    /// Probability a ghost moves toward the player (else random).
    pub ghost_smart: f64,
    /// Ghosts move once every this many player steps.
    pub ghost_period: u32,
    /// Fraction of cells holding a pellet at reset.
    pub pellet_density: f64,
    pub pellet_reward: f64,
    pub capture_penalty: f64,
    pub horizon: u32,
}

impl ChaseConfig {
    pub fn alien() -> Self {
        ChaseConfig {
            name: "Alien",
            width: 11,
            height: 11,
            num_ghosts: 3,
            ghost_smart: 0.75,
            ghost_period: 1,
            pellet_density: 0.35,
            pellet_reward: 10.0,
            capture_penalty: -50.0,
            horizon: 300,
        }
    }

    pub fn mspacman() -> Self {
        ChaseConfig {
            name: "MsPacman",
            width: 13,
            height: 13,
            num_ghosts: 4,
            ghost_smart: 0.5,
            ghost_period: 1,
            pellet_density: 0.45,
            pellet_reward: 10.0,
            capture_penalty: -100.0,
            horizon: 400,
        }
    }

    pub fn qbert() -> Self {
        ChaseConfig {
            name: "Qbert",
            width: 9,
            height: 9,
            num_ghosts: 2,
            ghost_smart: 0.6,
            ghost_period: 2,
            pellet_density: 1.0, // paint every cell
            pellet_reward: 25.0,
            capture_penalty: -25.0,
            horizon: 250,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ChaseGame {
    cfg: ChaseConfig,
    rng: Pcg32,
    player: (i64, i64),
    ghosts: Vec<(i64, i64)>,
    pellets: Vec<bool>, // width*height
    step: u32,
    caught: bool,
    score: f64,
}

const DIRS: [(i64, i64); 5] = [(0, -1), (0, 1), (-1, 0), (1, 0), (0, 0)];

impl ChaseGame {
    pub fn new(cfg: ChaseConfig, seed: u64) -> Self {
        let mut g = ChaseGame {
            cfg,
            rng: Pcg32::new(seed),
            player: (0, 0),
            ghosts: Vec::new(),
            pellets: Vec::new(),
            step: 0,
            caught: false,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    fn cell(&self, x: i64, y: i64) -> usize {
        (y * self.cfg.width + x) as usize
    }

    fn pellets_left(&self) -> usize {
        self.pellets.iter().filter(|&&p| p).count()
    }

    fn nearest_ghost_dist(&self) -> i64 {
        self.ghosts
            .iter()
            .map(|&(gx, gy)| (gx - self.player.0).abs() + (gy - self.player.1).abs())
            .min()
            .unwrap_or(i64::MAX)
    }

    fn nearest_pellet_dist_from(&self, x: i64, y: i64) -> i64 {
        let mut best = i64::MAX;
        for py in 0..self.cfg.height {
            for px in 0..self.cfg.width {
                if self.pellets[self.cell(px, py)] {
                    best = best.min((px - x).abs() + (py - y).abs());
                }
            }
        }
        best
    }

    fn clamp_pos(&self, x: i64, y: i64) -> (i64, i64) {
        (x.clamp(0, self.cfg.width - 1), y.clamp(0, self.cfg.height - 1))
    }
}

impl Env for ChaseGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        w.i64(self.player.0);
        w.i64(self.player.1);
        w.u32(self.ghosts.len() as u32);
        for &(x, y) in &self.ghosts {
            w.i64(x);
            w.i64(y);
        }
        let bytes: Vec<u8> = self.pellets.iter().map(|&b| b as u8).collect();
        w.bytes(&bytes);
        w.u32(self.step);
        w.u8(self.caught as u8);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.player = (r.i64(), r.i64());
        let n = r.u32() as usize;
        self.ghosts = (0..n).map(|_| (r.i64(), r.i64())).collect();
        self.pellets = r.bytes().iter().map(|&b| b != 0).collect();
        self.step = r.u32();
        self.caught = r.u8() != 0;
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0xc4a5e);
        self.player = (self.cfg.width / 2, self.cfg.height / 2);
        self.ghosts = (0..self.cfg.num_ghosts)
            .map(|i| {
                // Corners, spread deterministically.
                let corner = i % 4;
                (
                    if corner % 2 == 0 { 0 } else { self.cfg.width - 1 },
                    if corner < 2 { 0 } else { self.cfg.height - 1 },
                )
            })
            .collect();
        let cells = (self.cfg.width * self.cfg.height) as usize;
        self.pellets = (0..cells)
            .map(|i| {
                let pos = (i as i64 % self.cfg.width, i as i64 / self.cfg.width);
                pos != self.player && self.rng.chance(self.cfg.pellet_density)
            })
            .collect();
        self.step = 0;
        self.caught = false;
        self.score = 0.0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal chase state");
        assert!(action < 5, "chase action {action} out of range");
        let (dx, dy) = DIRS[action];
        self.player = self.clamp_pos(self.player.0 + dx, self.player.1 + dy);
        let mut reward = 0.0;
        let cell = self.cell(self.player.0, self.player.1);
        if self.pellets[cell] {
            self.pellets[cell] = false;
            reward += self.cfg.pellet_reward;
        }
        // Ghost moves.
        if self.cfg.ghost_period > 0 && self.step % self.cfg.ghost_period == 0 {
            let player = self.player;
            let smart = self.cfg.ghost_smart;
            for gi in 0..self.ghosts.len() {
                let (gx, gy) = self.ghosts[gi];
                let (nx, ny) = if self.rng.chance(smart) {
                    // Step along the larger axis toward the player.
                    let ddx = (player.0 - gx).signum();
                    let ddy = (player.1 - gy).signum();
                    if (player.0 - gx).abs() >= (player.1 - gy).abs() {
                        (gx + ddx, gy)
                    } else {
                        (gx, gy + ddy)
                    }
                } else {
                    let (rx, ry) = DIRS[self.rng.below_usize(4)];
                    (gx + rx, gy + ry)
                };
                self.ghosts[gi] = self.clamp_pos(nx, ny);
            }
        }
        if self.ghosts.iter().any(|&g| g == self.player) {
            self.caught = true;
            reward += self.cfg.capture_penalty;
        }
        self.step += 1;
        self.score += reward;
        let done = self.is_terminal();
        StepResult { reward, done }
    }

    fn legal_actions(&self) -> Vec<usize> {
        (0..5).collect()
    }

    fn num_actions(&self) -> usize {
        5
    }

    fn is_terminal(&self) -> bool {
        self.caught || self.step >= self.cfg.horizon || self.pellets_left() == 0
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        if action >= 5 {
            return 0.0;
        }
        let (dx, dy) = DIRS[action];
        let (nx, ny) = self.clamp_pos(self.player.0 + dx, self.player.1 + dy);
        // Prefer moves onto pellets / toward the nearest pellet, away from
        // ghosts when close.
        let mut score: f64 = 0.3;
        if self.pellets[self.cell(nx, ny)] {
            score += 0.5;
        } else {
            let here = self.nearest_pellet_dist_from(self.player.0, self.player.1);
            let there = self.nearest_pellet_dist_from(nx, ny);
            if there < here {
                score += 0.25;
            }
        }
        let ghost_d = self
            .ghosts
            .iter()
            .map(|&(gx, gy)| (gx - nx).abs() + (gy - ny).abs())
            .min()
            .unwrap_or(i64::MAX);
        if ghost_d <= 1 {
            score -= 0.6;
        } else if ghost_d == 2 {
            score -= 0.2;
        }
        score.clamp(0.0, 1.0)
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        if self.caught {
            return -1.0;
        }
        let total = self.pellets.len() as f64 * self.cfg.pellet_density;
        let eaten = total - self.pellets_left() as f64;
        let danger = if self.nearest_ghost_dist() <= 2 { 0.4 } else { 0.0 };
        ((eaten / total.max(1.0)) - danger).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        if out.len() < 8 {
            return;
        }
        out[0] = self.player.0 as f32 / self.cfg.width as f32;
        out[1] = self.player.1 as f32 / self.cfg.height as f32;
        out[2] = self.pellets_left() as f32 / self.pellets.len() as f32;
        out[3] = (self.nearest_ghost_dist().min(20) as f32) / 20.0;
        for (i, &(gx, gy)) in self.ghosts.iter().take(2).enumerate() {
            out[4 + 2 * i] = gx as f32 / self.cfg.width as f32;
            out[5 + 2 * i] = gy as f32 / self.cfg.height as f32;
        }
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_player_center_no_pellet_under_player() {
        let g = ChaseGame::new(ChaseConfig::alien(), 3);
        let cell = g.cell(g.player.0, g.player.1);
        assert!(!g.pellets[cell]);
        assert!(!g.is_terminal());
    }

    #[test]
    fn eating_pellet_rewards() {
        let mut g = ChaseGame::new(ChaseConfig::mspacman(), 5);
        // Find an adjacent pellet and step onto it.
        let mut got = false;
        for a in 0..4 {
            let (dx, dy) = DIRS[a];
            let (nx, ny) = g.clamp_pos(g.player.0 + dx, g.player.1 + dy);
            if g.pellets[g.cell(nx, ny)] {
                let r = g.step(a);
                assert!(r.reward >= g.cfg.pellet_reward);
                got = true;
                break;
            }
        }
        if !got {
            // No adjacent pellet on this seed — at minimum stepping works.
            g.step(4);
        }
    }

    #[test]
    fn capture_is_penalized_and_terminal() {
        let mut g = ChaseGame::new(ChaseConfig::alien(), 1);
        // Teleport a ghost next to the player via restore surgery:
        g.ghosts[0] = (g.player.0, g.player.1 + 1);
        g.cfg.ghost_smart = 1.0;
        let r = g.step(4); // stay; smart ghost steps onto us
        if g.caught {
            assert!(r.reward <= g.cfg.capture_penalty + g.cfg.pellet_reward);
            assert!(g.is_terminal());
        }
    }

    #[test]
    fn snapshot_restore_replays_identically() {
        let mut g = ChaseGame::new(ChaseConfig::qbert(), 9);
        for _ in 0..5 {
            g.step(g.legal_actions()[1]);
        }
        let snap = g.snapshot();
        let mut h = ChaseGame::new(ChaseConfig::qbert(), 0);
        h.restore(&snap);
        for _ in 0..10 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(2), h.step(2));
        }
    }

    #[test]
    fn horizon_bounds_episode() {
        let mut cfg = ChaseConfig::alien();
        cfg.horizon = 10;
        cfg.num_ghosts = 0;
        cfg.pellet_density = 1.0;
        let mut g = ChaseGame::new(cfg, 2);
        let mut n = 0;
        while !g.is_terminal() {
            g.step(4);
            n += 1;
            assert!(n <= 10);
        }
    }

    #[test]
    fn heuristic_avoids_ghost() {
        let mut g = ChaseGame::new(ChaseConfig::alien(), 4);
        g.ghosts = vec![(g.player.0 + 1, g.player.1)]; // right of player
        let right = g.action_heuristic(3);
        let left = g.action_heuristic(2);
        assert!(left > right, "moving into a ghost must score lower");
    }

    #[test]
    fn all_pellets_eaten_terminates() {
        let mut cfg = ChaseConfig::alien();
        cfg.num_ghosts = 0;
        cfg.pellet_density = 0.02;
        let mut g = ChaseGame::new(cfg, 8);
        while !g.is_terminal() {
            // Greedy walk toward the nearest pellet.
            let acts = g.legal_actions();
            let best = acts
                .into_iter()
                .max_by(|&a, &b| {
                    g.action_heuristic(a)
                        .partial_cmp(&g.action_heuristic(b))
                        .unwrap()
                })
                .unwrap();
            g.step(best);
        }
        assert!(g.pellets_left() == 0 || g.step >= g.cfg.horizon);
    }
}
