//! Racer mechanic: dodge obstacles, grab boosts on a scrolling 3-lane road
//! (RoadRunner analogue).
//!
//! Actions: 0=left 1=right 2=stay. The road scrolls one row per step; each
//! arriving row holds obstacles / seeds drawn from the config densities.
//! Hitting an obstacle costs `crash_penalty` and stuns briefly; seeds give
//! dense reward — RoadRunner's big-score, dense-reward profile.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct RacerConfig {
    pub name: &'static str,
    pub lanes: i64,
    /// Visible lookahead rows (affects features only).
    pub lookahead: i64,
    pub p_obstacle: f64,
    pub p_seed: f64,
    pub seed_reward: f64,
    pub crash_penalty: f64,
    pub horizon: u32,
}

impl RacerConfig {
    pub fn road_runner() -> Self {
        RacerConfig {
            name: "RoadRunner",
            lanes: 3,
            lookahead: 4,
            p_obstacle: 0.25,
            p_seed: 0.35,
            seed_reward: 100.0,
            crash_penalty: -200.0,
            horizon: 450,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RacerGame {
    cfg: RacerConfig,
    rng: Pcg32,
    lane: i64,
    /// Upcoming rows, index 0 arrives next. Each row: per-lane cell code
    /// (0 empty, 1 obstacle, 2 seed).
    road: Vec<Vec<u8>>,
    stun: u32,
    step: u32,
    score: f64,
}

impl RacerGame {
    pub fn new(cfg: RacerConfig, seed: u64) -> Self {
        let mut g = RacerGame {
            cfg,
            rng: Pcg32::new(seed),
            lane: 0,
            road: Vec::new(),
            stun: 0,
            step: 0,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    fn gen_row(&mut self) -> Vec<u8> {
        let mut row = vec![0u8; self.cfg.lanes as usize];
        let mut open = false;
        for cell in row.iter_mut() {
            if self.rng.chance(self.cfg.p_obstacle) {
                *cell = 1;
            } else {
                open = true;
                if self.rng.chance(self.cfg.p_seed) {
                    *cell = 2;
                }
            }
        }
        if !open {
            // Guarantee a passable gap.
            let gap = self.rng.below(self.cfg.lanes as u32) as usize;
            row[gap] = 0;
        }
        row
    }
}

impl Env for RacerGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        w.i64(self.lane);
        w.u32(self.road.len() as u32);
        for row in &self.road {
            w.bytes(row);
        }
        w.u32(self.stun);
        w.u32(self.step);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.lane = r.i64();
        let n = r.u32() as usize;
        self.road = (0..n).map(|_| r.bytes().to_vec()).collect();
        self.stun = r.u32();
        self.step = r.u32();
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0xace5);
        self.lane = self.cfg.lanes / 2;
        self.road = Vec::new();
        for _ in 0..self.cfg.lookahead {
            let row = self.gen_row();
            self.road.push(row);
        }
        self.stun = 0;
        self.step = 0;
        self.score = 0.0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal racer state");
        assert!(action < 3, "racer action {action} out of range");
        if self.stun == 0 {
            match action {
                0 => self.lane = (self.lane - 1).max(0),
                1 => self.lane = (self.lane + 1).min(self.cfg.lanes - 1),
                _ => {}
            }
        } else {
            self.stun -= 1;
        }
        // The next row arrives under the player.
        let row = self.road.remove(0);
        let mut reward = 0.0;
        match row[self.lane as usize] {
            1 => {
                reward += self.cfg.crash_penalty;
                self.stun = 2;
            }
            2 => reward += self.cfg.seed_reward,
            _ => {}
        }
        let new_row = self.gen_row();
        self.road.push(new_row);
        self.step += 1;
        self.score += reward;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        vec![0, 1, 2]
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn is_terminal(&self) -> bool {
        self.step >= self.cfg.horizon
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        let target = match action {
            0 => (self.lane - 1).max(0),
            1 => (self.lane + 1).min(self.cfg.lanes - 1),
            _ => self.lane,
        };
        match self.road[0][target as usize] {
            1 => 0.02,          // obstacle: terrible
            2 => 0.95,          // seed: excellent
            _ => match self.road.get(1).map(|r| r[target as usize]) {
                Some(2) => 0.6, // lines up a seed
                Some(1) => 0.3,
                _ => 0.45,
            },
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        // Score pace vs the expected seed pace.
        let expected = self.step.max(1) as f64 * self.cfg.p_seed * self.cfg.seed_reward * 0.5;
        ((self.score / expected.max(1.0)) - 0.5).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        let mut k = 0;
        if out.is_empty() {
            return;
        }
        out[k] = self.lane as f32 / (self.cfg.lanes - 1).max(1) as f32;
        k += 1;
        'outer: for row in self.road.iter().take(3) {
            for &cell in row.iter() {
                if k >= out.len() {
                    break 'outer;
                }
                out[k] = cell as f32 / 2.0;
                k += 1;
            }
        }
        if k < out.len() {
            out[k] = (self.stun > 0) as u8 as f32;
        }
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn road_always_has_lookahead_rows() {
        let mut g = RacerGame::new(RacerConfig::road_runner(), 1);
        for i in 0..50 {
            if g.is_terminal() {
                break;
            }
            g.step(i % 3);
            assert_eq!(g.road.len() as i64, g.cfg.lookahead);
        }
    }

    #[test]
    fn every_row_has_a_gap() {
        let mut g = RacerGame::new(RacerConfig::road_runner(), 2);
        for _ in 0..200 {
            let row = g.gen_row();
            assert!(row.iter().any(|&c| c != 1), "row {row:?} impassable");
        }
    }

    #[test]
    fn heuristic_dodges_obstacles() {
        let mut g = RacerGame::new(RacerConfig::road_runner(), 3);
        // Construct a row with an obstacle under stay and a seed left.
        g.road[0] = vec![2, 1, 0];
        g.lane = 1;
        assert!(g.action_heuristic(0) > g.action_heuristic(2));
    }

    #[test]
    fn heuristic_play_beats_static() {
        let run = |smart: bool, seed| {
            let mut g = RacerGame::new(RacerConfig::road_runner(), seed);
            while !g.is_terminal() {
                let a = if smart {
                    (0..3)
                        .max_by(|&x, &y| {
                            g.action_heuristic(x)
                                .partial_cmp(&g.action_heuristic(y))
                                .unwrap()
                        })
                        .unwrap()
                } else {
                    2
                };
                g.step(a);
            }
            g.score
        };
        let smart: f64 = (0..6).map(|s| run(true, s)).sum();
        let dumb: f64 = (0..6).map(|s| run(false, s)).sum();
        assert!(smart > dumb, "smart {smart} vs static {dumb}");
    }

    #[test]
    fn stun_blocks_movement() {
        let mut g = RacerGame::new(RacerConfig::road_runner(), 4);
        g.road[0] = vec![1, 1, 1];
        g.road[0][g.lane as usize] = 1;
        let lane_before = g.lane;
        g.step(2); // crash
        assert!(g.stun > 0);
        g.road[0] = vec![0, 0, 0];
        g.step(0); // stunned: no move
        assert_eq!(g.lane, lane_before);
    }

    #[test]
    fn snapshot_restore_replay() {
        let mut g = RacerGame::new(RacerConfig::road_runner(), 5);
        for _ in 0..13 {
            g.step(1);
        }
        let snap = g.snapshot();
        let mut h = RacerGame::new(RacerConfig::road_runner(), 50);
        h.restore(&snap);
        for i in 0..40 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(i % 3), h.step(i % 3));
        }
    }
}
