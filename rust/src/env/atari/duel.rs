//! Duel mechanic: trade blows with a scripted opponent (Boxing / Tennis /
//! Robotank analogue).
//!
//! Both fighters stand at integer positions on a line. Actions: 0=advance
//! 1=retreat 2=strike 3=guard. A strike lands iff in range and the
//! opponent is not guarding; the scripted opponent mixes advancing,
//! guarding and striking with config-dependent skill. Score is the hit
//! differential — Boxing saturates near the cap, Tennis stays low and
//! sparse, exactly the profiles in Table 1.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct DuelConfig {
    pub name: &'static str,
    pub arena: i64,
    pub range: i64,
    pub hit_reward: f64,
    pub take_penalty: f64,
    /// Probability the opponent guards when we are in range.
    pub opp_guard: f64,
    /// Probability the opponent strikes when in range (after guard roll).
    pub opp_strike: f64,
    /// Score cap: the episode ends when |differential| reaches it.
    pub cap: f64,
    pub horizon: u32,
}

impl DuelConfig {
    pub fn boxing() -> Self {
        DuelConfig {
            name: "Boxing",
            arena: 12,
            range: 2,
            hit_reward: 1.0,
            take_penalty: -1.0,
            opp_guard: 0.25,
            opp_strike: 0.3,
            cap: 100.0,
            horizon: 400,
        }
    }

    pub fn tennis() -> Self {
        DuelConfig {
            name: "Tennis",
            arena: 16,
            range: 1,
            hit_reward: 1.0,
            take_penalty: -1.0,
            opp_guard: 0.55,
            opp_strike: 0.5,
            cap: 6.0,
            horizon: 300,
        }
    }

    pub fn robotank() -> Self {
        DuelConfig {
            name: "Robotank",
            arena: 14,
            range: 3,
            hit_reward: 4.0,
            take_penalty: -2.0,
            opp_guard: 0.35,
            opp_strike: 0.35,
            cap: 120.0,
            horizon: 450,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DuelGame {
    cfg: DuelConfig,
    rng: Pcg32,
    me: i64,
    opp: i64,
    /// Whether each side guarded last tick (guards block incoming strikes
    /// resolved this tick).
    my_guard: bool,
    opp_guard_up: bool,
    step: u32,
    score: f64,
}

impl DuelGame {
    pub fn new(cfg: DuelConfig, seed: u64) -> Self {
        let mut g = DuelGame {
            cfg,
            rng: Pcg32::new(seed),
            me: 0,
            opp: 0,
            my_guard: false,
            opp_guard_up: false,
            step: 0,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    fn in_range(&self) -> bool {
        (self.me - self.opp).abs() <= self.cfg.range
    }
}

impl Env for DuelGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        w.i64(self.me);
        w.i64(self.opp);
        w.u8(self.my_guard as u8);
        w.u8(self.opp_guard_up as u8);
        w.u32(self.step);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.me = r.i64();
        self.opp = r.i64();
        self.my_guard = r.u8() != 0;
        self.opp_guard_up = r.u8() != 0;
        self.step = r.u32();
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0xd0e1);
        self.me = 1;
        self.opp = self.cfg.arena - 2;
        self.my_guard = false;
        self.opp_guard_up = false;
        self.step = 0;
        self.score = 0.0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal duel state");
        assert!(action < 4, "duel action {action} out of range");
        let mut reward = 0.0;
        // Opponent decides first (simultaneous resolution, scripted AI).
        let opp_in_range = self.in_range();
        let (opp_strikes, opp_guards, opp_move): (bool, bool, i64) = if opp_in_range {
            if self.rng.chance(self.cfg.opp_guard) {
                (false, true, 0)
            } else if self.rng.chance(self.cfg.opp_strike) {
                (true, false, 0)
            } else {
                (false, false, (self.me - self.opp).signum())
            }
        } else {
            (false, false, (self.me - self.opp).signum())
        };
        // Apply my action.
        let toward = (self.opp - self.me).signum();
        let mut i_strike = false;
        match action {
            0 => self.me = (self.me + toward).clamp(0, self.cfg.arena - 1),
            1 => self.me = (self.me - toward).clamp(0, self.cfg.arena - 1),
            2 => i_strike = true,
            _ => {}
        }
        self.my_guard = action == 3;
        // Opponent move.
        self.opp = (self.opp + opp_move).clamp(0, self.cfg.arena - 1);
        if self.me == self.opp {
            // Never share a cell: opponent steps back.
            self.opp = (self.opp - toward).clamp(0, self.cfg.arena - 1);
        }
        let in_range = self.in_range();
        // Resolve strikes.
        if i_strike && in_range && !opp_guards {
            reward += self.cfg.hit_reward;
        }
        if opp_strikes && in_range && !self.my_guard {
            reward += self.cfg.take_penalty;
        }
        self.opp_guard_up = opp_guards;
        self.step += 1;
        self.score += reward;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        vec![0, 1, 2, 3]
    }

    fn num_actions(&self) -> usize {
        4
    }

    fn is_terminal(&self) -> bool {
        self.step >= self.cfg.horizon || self.score.abs() >= self.cfg.cap
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        let in_range = self.in_range();
        match action {
            2 if in_range && !self.opp_guard_up => 0.9,
            2 => 0.15,
            0 if !in_range => 0.8,
            0 => 0.3,
            3 if in_range => 0.5,
            3 => 0.15,
            1 => 0.1,
            _ => 0.0,
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        (self.score / self.cfg.cap).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        if out.len() < 5 {
            return;
        }
        out[0] = self.me as f32 / self.cfg.arena as f32;
        out[1] = self.opp as f32 / self.cfg.arena as f32;
        out[2] = ((self.me - self.opp).abs() as f32) / self.cfg.arena as f32;
        out[3] = self.opp_guard_up as u8 as f32;
        out[4] = (self.score / self.cfg.cap) as f32;
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn greedy(g: &DuelGame) -> usize {
        (0..4)
            .max_by(|&a, &b| {
                g.action_heuristic(a)
                    .partial_cmp(&g.action_heuristic(b))
                    .unwrap()
            })
            .unwrap()
    }

    #[test]
    fn fighters_start_apart() {
        let g = DuelGame::new(DuelConfig::boxing(), 1);
        assert!(!g.in_range());
        assert!(!g.is_terminal());
    }

    #[test]
    fn advance_closes_distance() {
        let mut g = DuelGame::new(DuelConfig::boxing(), 2);
        let d0 = (g.me - g.opp).abs();
        g.step(0);
        let d1 = (g.me - g.opp).abs();
        assert!(d1 <= d0, "advance + opp advance must not widen the gap");
    }

    #[test]
    fn strike_out_of_range_misses() {
        let mut g = DuelGame::new(DuelConfig::tennis(), 3);
        assert!(!g.in_range());
        let r = g.step(2);
        assert!(r.reward <= 0.0, "out-of-range strike cannot score");
    }

    #[test]
    fn greedy_play_outpoints_opponent() {
        let mut wins = 0;
        for seed in 0..10 {
            let mut g = DuelGame::new(DuelConfig::boxing(), seed);
            while !g.is_terminal() {
                let a = greedy(&g);
                g.step(a);
            }
            if g.score > 0.0 {
                wins += 1;
            }
        }
        assert!(wins >= 7, "greedy should usually win Boxing: {wins}/10");
    }

    #[test]
    fn tennis_is_hard_and_low_scoring() {
        let mut total = 0.0;
        for seed in 0..10 {
            let mut g = DuelGame::new(DuelConfig::tennis(), seed);
            while !g.is_terminal() {
                g.step(greedy(&g));
            }
            total += g.score;
            assert!(g.score.abs() <= g.cfg.cap);
        }
        // Tennis scores stay in single digits per episode by construction.
        assert!(total.abs() <= 60.0);
    }

    #[test]
    fn score_cap_terminates() {
        let mut g = DuelGame::new(DuelConfig::boxing(), 4);
        g.score = g.cfg.cap;
        assert!(g.is_terminal());
    }

    #[test]
    fn snapshot_restore_replay() {
        let mut g = DuelGame::new(DuelConfig::robotank(), 5);
        for _ in 0..9 {
            g.step(0);
        }
        let snap = g.snapshot();
        let mut h = DuelGame::new(DuelConfig::robotank(), 42);
        h.restore(&snap);
        for i in 0..30 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(i % 4), h.step(i % 4));
        }
    }

    #[test]
    fn guard_blocks_damage() {
        // With permanent guard, we can never lose points from strikes.
        let mut g = DuelGame::new(DuelConfig::boxing(), 6);
        let mut worst = 0.0f64;
        for _ in 0..100 {
            if g.is_terminal() {
                break;
            }
            let r = g.step(3);
            worst = worst.min(r.reward);
        }
        assert!(worst >= 0.0, "guarded player must not take hits");
    }
}
