//! The 15-game synthetic Atari suite (Table 1's environment column).
//!
//! Each named game instantiates one of the six mechanics with parameters
//! chosen to echo the corresponding Atari title's character: horizon,
//! reward scale/sparsity, difficulty and variance. The *names* match the
//! paper's tables so every experiment harness prints directly comparable
//! rows.

use crate::env::atari::chase::{ChaseConfig, ChaseGame};
use crate::env::atari::crossing::{CrossingConfig, CrossingGame};
use crate::env::atari::duel::{DuelConfig, DuelGame};
use crate::env::atari::paddle::{PaddleConfig, PaddleGame};
use crate::env::atari::racer::{RacerConfig, RacerGame};
use crate::env::atari::shooter::{ShooterConfig, ShooterGame};
use crate::env::Env;

/// The 15 game names, in the paper's Table-1 order.
pub const GAMES: [&str; 15] = [
    "Alien",
    "Boxing",
    "Breakout",
    "Centipede",
    "Freeway",
    "Gravitar",
    "MsPacman",
    "NameThisGame",
    "RoadRunner",
    "Robotank",
    "Qbert",
    "SpaceInvaders",
    "Tennis",
    "TimePilot",
    "Zaxxon",
];

/// The 4 games used in Fig. 5's worker sweep.
pub const FIG5_GAMES: [&str; 4] = ["Alien", "Boxing", "Breakout", "Freeway"];

/// The 12 games used in Table 5's TreeP-variant comparison.
pub const TABLE5_GAMES: [&str; 12] = [
    "Alien",
    "Boxing",
    "Breakout",
    "Freeway",
    "Gravitar",
    "MsPacman",
    "RoadRunner",
    "Qbert",
    "SpaceInvaders",
    "Tennis",
    "TimePilot",
    "Zaxxon",
];

/// Construct a game by its Table-1 name.
pub fn make(name: &str, seed: u64) -> Box<dyn Env> {
    match name {
        "Alien" => Box::new(ChaseGame::new(ChaseConfig::alien(), seed)),
        "MsPacman" => Box::new(ChaseGame::new(ChaseConfig::mspacman(), seed)),
        "Qbert" => Box::new(ChaseGame::new(ChaseConfig::qbert(), seed)),
        "Breakout" => Box::new(PaddleGame::new(PaddleConfig::breakout(), seed)),
        "NameThisGame" => Box::new(PaddleGame::new(PaddleConfig::namethisgame(), seed)),
        "SpaceInvaders" => Box::new(ShooterGame::new(ShooterConfig::space_invaders(), seed)),
        "Centipede" => Box::new(ShooterGame::new(ShooterConfig::centipede(), seed)),
        "TimePilot" => Box::new(ShooterGame::new(ShooterConfig::time_pilot(), seed)),
        "Zaxxon" => Box::new(ShooterGame::new(ShooterConfig::zaxxon(), seed)),
        "Freeway" => Box::new(CrossingGame::new(CrossingConfig::freeway(), seed)),
        "Gravitar" => Box::new(CrossingGame::new(CrossingConfig::gravitar(), seed)),
        "RoadRunner" => Box::new(RacerGame::new(RacerConfig::road_runner(), seed)),
        "Boxing" => Box::new(DuelGame::new(DuelConfig::boxing(), seed)),
        "Tennis" => Box::new(DuelGame::new(DuelConfig::tennis(), seed)),
        "Robotank" => Box::new(DuelGame::new(DuelConfig::robotank(), seed)),
        other => panic!("unknown game {other:?}; see suite::GAMES"),
    }
}

/// All 15 games, freshly constructed with `seed`.
pub fn all(seed: u64) -> Vec<Box<dyn Env>> {
    GAMES.iter().map(|name| make(name, seed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{FEATURE_DIM, MAX_ACTIONS};

    #[test]
    fn all_fifteen_construct_and_are_playable() {
        for name in GAMES {
            let mut env = make(name, 1);
            assert_eq!(env.name(), name);
            assert!(!env.is_terminal(), "{name} starts terminal");
            let acts = env.legal_actions();
            assert!(!acts.is_empty(), "{name} has no legal actions");
            assert!(env.num_actions() <= MAX_ACTIONS);
            let r = env.step(acts[0]);
            assert!(r.reward.is_finite());
        }
    }

    #[test]
    fn features_conform_to_contract_for_every_game() {
        use crate::env::{FEAT_FRAC_INDEX, FEAT_MASK_OFFSET, FEAT_VALUE_INDEX};
        for name in GAMES {
            let env = make(name, 3);
            let mut f = vec![0f32; FEATURE_DIM];
            env.features(&mut f);
            let legal = env.legal_actions();
            for a in 0..MAX_ACTIONS {
                let is_legal = legal.contains(&a);
                assert_eq!(
                    f[FEAT_MASK_OFFSET + a] > 0.5,
                    is_legal,
                    "{name}: mask mismatch at {a}"
                );
            }
            assert!((0.0..=1.0).contains(&f[FEAT_FRAC_INDEX]), "{name}");
            assert!((-1.0..=1.0).contains(&f[FEAT_VALUE_INDEX]), "{name}");
        }
    }

    #[test]
    fn snapshots_replay_for_every_game() {
        for name in GAMES {
            let mut env = make(name, 7);
            // Advance a few steps.
            for _ in 0..4 {
                if env.is_terminal() {
                    break;
                }
                let a = env.legal_actions()[0];
                env.step(a);
            }
            let snap = env.snapshot();
            let mut copy = make(name, 999);
            copy.restore(&snap);
            for _ in 0..6 {
                if env.is_terminal() {
                    break;
                }
                let a = env.legal_actions()[0];
                assert_eq!(env.step(a), copy.step(a), "{name} snapshot replay");
            }
        }
    }

    #[test]
    fn episodes_terminate_within_bounded_steps() {
        for name in GAMES {
            let mut env = make(name, 11);
            let mut n = 0u32;
            while !env.is_terminal() {
                let acts = env.legal_actions();
                env.step(acts[n as usize % acts.len()]);
                n += 1;
                assert!(n < 3000, "{name} failed to terminate");
            }
        }
    }

    #[test]
    fn clone_boxed_is_independent() {
        for name in GAMES {
            let env = make(name, 13);
            let mut a = env.clone_boxed();
            let mut b = env.clone_boxed();
            let act = a.legal_actions()[0];
            let ra = a.step(act);
            let rb = b.step(act);
            assert_eq!(ra, rb, "{name}: clones must evolve identically");
        }
    }

    #[test]
    #[should_panic(expected = "unknown game")]
    fn unknown_name_panics() {
        make("Pong", 0);
    }

    #[test]
    fn subsets_are_subsets() {
        for g in FIG5_GAMES {
            assert!(GAMES.contains(&g));
        }
        for g in TABLE5_GAMES {
            assert!(GAMES.contains(&g));
        }
    }
}
