//! Shooter mechanic: waves of descending enemies, player shoots columns
//! (SpaceInvaders / Centipede / TimePilot analogue).
//!
//! Actions: 0=left 1=right 2=shoot 3=stay. Shooting destroys the lowest
//! enemy in the player's column (bullets are instantaneous — a one-cell
//! world keeps the tree branching on *tactics*, not physics). Enemies
//! march sideways and descend at the walls; an enemy reaching the player
//! row ends the episode with a penalty. Waves respawn (`waves` knob) which
//! gives Centipede its huge score scale.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct ShooterConfig {
    pub name: &'static str,
    pub width: i64,
    pub height: i64,
    pub rows: i64,
    /// Probability each cell of a wave row holds an enemy.
    pub density: f64,
    pub kill_reward: f64,
    pub breach_penalty: f64,
    /// Enemies advance once every `enemy_period` steps.
    pub enemy_period: u32,
    /// Number of waves (respawns) before the board stays clear.
    pub waves: u32,
    pub horizon: u32,
}

impl ShooterConfig {
    pub fn space_invaders() -> Self {
        ShooterConfig {
            name: "SpaceInvaders",
            width: 11,
            height: 10,
            rows: 3,
            density: 0.7,
            kill_reward: 10.0,
            breach_penalty: -100.0,
            enemy_period: 3,
            waves: 3,
            horizon: 350,
        }
    }

    pub fn centipede() -> Self {
        ShooterConfig {
            name: "Centipede",
            width: 13,
            height: 9,
            rows: 2,
            density: 0.8,
            kill_reward: 60.0, // Centipede's score scale is enormous
            breach_penalty: -300.0,
            enemy_period: 2,
            waves: 8,
            horizon: 400,
        }
    }

    pub fn time_pilot() -> Self {
        ShooterConfig {
            name: "TimePilot",
            width: 12,
            height: 11,
            rows: 2,
            density: 0.5,
            kill_reward: 25.0,
            breach_penalty: -150.0,
            enemy_period: 2,
            waves: 5,
            horizon: 350,
        }
    }

    pub fn zaxxon() -> Self {
        ShooterConfig {
            name: "Zaxxon",
            width: 12,
            height: 12,
            rows: 3,
            density: 0.45,
            kill_reward: 30.0,
            breach_penalty: -200.0,
            enemy_period: 3,
            waves: 4,
            horizon: 400,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ShooterGame {
    cfg: ShooterConfig,
    rng: Pcg32,
    /// Enemy occupancy grid, rows 0..height-1 (player lives at height-1).
    enemies: Vec<bool>,
    player_x: i64,
    /// March direction of the wave (+1 / -1).
    dir: i64,
    wave: u32,
    step: u32,
    breached: bool,
    score: f64,
}

impl ShooterGame {
    pub fn new(cfg: ShooterConfig, seed: u64) -> Self {
        let mut g = ShooterGame {
            cfg,
            rng: Pcg32::new(seed),
            enemies: Vec::new(),
            player_x: 0,
            dir: 1,
            wave: 0,
            step: 0,
            breached: false,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    fn cell(&self, x: i64, y: i64) -> usize {
        (y * self.cfg.width + x) as usize
    }

    fn enemies_left(&self) -> usize {
        self.enemies.iter().filter(|&&e| e).count()
    }

    fn spawn_wave(&mut self) {
        let cells = (self.cfg.width * self.cfg.height) as usize;
        self.enemies = vec![false; cells];
        for y in 0..self.cfg.rows {
            for x in 0..self.cfg.width {
                if self.rng.chance(self.cfg.density) {
                    let i = self.cell(x, y);
                    self.enemies[i] = true;
                }
            }
        }
        self.dir = 1;
    }

    /// Lowest enemy in column `x`, if any.
    fn lowest_in_column(&self, x: i64) -> Option<i64> {
        (0..self.cfg.height).rev().find(|&y| self.enemies[self.cell(x, y)])
    }

    fn march(&mut self) {
        // March sideways; descend + reverse at a wall.
        let at_wall = (0..self.cfg.height).any(|y| {
            let edge = if self.dir > 0 { self.cfg.width - 1 } else { 0 };
            self.enemies[self.cell(edge, y)]
        });
        let cells = self.enemies.len();
        let mut next = vec![false; cells];
        if at_wall {
            // Descend one row.
            for y in (0..self.cfg.height - 1).rev() {
                for x in 0..self.cfg.width {
                    if self.enemies[self.cell(x, y)] {
                        next[self.cell(x, y + 1)] = true;
                    }
                }
            }
            // Anything already at the bottom row breaches.
            for x in 0..self.cfg.width {
                if self.enemies[self.cell(x, self.cfg.height - 1)] {
                    self.breached = true;
                }
            }
            self.dir = -self.dir;
        } else {
            for y in 0..self.cfg.height {
                for x in 0..self.cfg.width {
                    if self.enemies[self.cell(x, y)] {
                        next[self.cell(x + self.dir, y)] = true;
                    }
                }
            }
        }
        self.enemies = next;
        if (0..self.cfg.width).any(|x| self.enemies[self.cell(x, self.cfg.height - 1)]) {
            self.breached = true;
        }
    }
}

impl Env for ShooterGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        let bytes: Vec<u8> = self.enemies.iter().map(|&b| b as u8).collect();
        w.bytes(&bytes);
        w.i64(self.player_x);
        w.i64(self.dir);
        w.u32(self.wave);
        w.u32(self.step);
        w.u8(self.breached as u8);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.enemies = r.bytes().iter().map(|&b| b != 0).collect();
        self.player_x = r.i64();
        self.dir = r.i64();
        self.wave = r.u32();
        self.step = r.u32();
        self.breached = r.u8() != 0;
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0x5607);
        self.player_x = self.cfg.width / 2;
        self.wave = 0;
        self.step = 0;
        self.breached = false;
        self.score = 0.0;
        self.spawn_wave();
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal shooter state");
        assert!(action < 4, "shooter action {action} out of range");
        let mut reward = 0.0;
        match action {
            0 => self.player_x = (self.player_x - 1).max(0),
            1 => self.player_x = (self.player_x + 1).min(self.cfg.width - 1),
            2 => {
                if let Some(y) = self.lowest_in_column(self.player_x) {
                    let i = self.cell(self.player_x, y);
                    self.enemies[i] = false;
                    reward += self.cfg.kill_reward;
                }
            }
            _ => {}
        }
        if self.enemies_left() == 0 && self.wave + 1 < self.cfg.waves {
            self.wave += 1;
            self.spawn_wave();
        } else if self.cfg.enemy_period > 0 && self.step % self.cfg.enemy_period == 0 {
            self.march();
        }
        if self.breached {
            reward += self.cfg.breach_penalty;
        }
        self.step += 1;
        self.score += reward;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        vec![0, 1, 2, 3]
    }

    fn num_actions(&self) -> usize {
        4
    }

    fn is_terminal(&self) -> bool {
        self.breached
            || self.step >= self.cfg.horizon
            || (self.enemies_left() == 0 && self.wave + 1 >= self.cfg.waves)
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        match action {
            2 => {
                // Shooting is great iff an enemy is in our column.
                if self.lowest_in_column(self.player_x).is_some() {
                    0.95
                } else {
                    0.05
                }
            }
            0 | 1 => {
                let dx = if action == 0 { -1 } else { 1 };
                let nx = (self.player_x + dx).clamp(0, self.cfg.width - 1);
                // Prefer moving toward the densest nearby column.
                let count = |x: i64| -> i64 {
                    if !(0..self.cfg.width).contains(&x) {
                        return -1;
                    }
                    (0..self.cfg.height)
                        .filter(|&y| self.enemies[self.cell(x, y)])
                        .count() as i64
                };
                if count(nx) > count(self.player_x) {
                    0.7
                } else {
                    0.25
                }
            }
            3 => 0.2,
            _ => 0.0,
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        if self.breached {
            return -1.0;
        }
        let total_per_wave =
            (self.cfg.rows * self.cfg.width) as f64 * self.cfg.density;
        let killed = self.wave as f64 * total_per_wave
            + (total_per_wave - self.enemies_left() as f64).max(0.0);
        let max = self.cfg.waves as f64 * total_per_wave;
        // Danger: enemies close to the bottom.
        let depth = (0..self.cfg.height)
            .rev()
            .find(|&y| (0..self.cfg.width).any(|x| self.enemies[self.cell(x, y)]));
        let danger = depth.map_or(0.0, |d| d as f64 / self.cfg.height as f64 * 0.5);
        (killed / max - danger).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        if out.len() < 6 {
            return;
        }
        out[0] = self.player_x as f32 / self.cfg.width as f32;
        out[1] = self.enemies_left() as f32 / (self.cfg.rows * self.cfg.width) as f32;
        out[2] = self.wave as f32 / self.cfg.waves as f32;
        out[3] = (self.dir as f32 + 1.0) / 2.0;
        out[4] = self.lowest_in_column(self.player_x).map_or(0.0, |y| y as f32)
            / self.cfg.height as f32;
        out[5] = self.breached as u8 as f32;
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_spawns_wave() {
        let g = ShooterGame::new(ShooterConfig::space_invaders(), 1);
        assert!(g.enemies_left() > 0);
        assert!(!g.is_terminal());
    }

    #[test]
    fn shooting_kills_lowest_in_column() {
        let mut g = ShooterGame::new(ShooterConfig::space_invaders(), 2);
        if let Some(y) = g.lowest_in_column(g.player_x) {
            let before = g.enemies_left();
            let r = g.step(2);
            assert!(r.reward >= g.cfg.kill_reward);
            assert_eq!(g.enemies_left(), before - 1);
            let _ = y;
        }
    }

    #[test]
    fn shooting_empty_column_wastes_turn() {
        let mut g = ShooterGame::new(ShooterConfig::space_invaders(), 3);
        // Clear our column first.
        while g.lowest_in_column(g.player_x).is_some() {
            g.step(2);
            if g.is_terminal() {
                return;
            }
        }
        let before = g.enemies_left();
        let r = g.step(2);
        assert!(r.reward <= 0.0);
        assert!(g.enemies_left() >= before.saturating_sub(0));
    }

    #[test]
    fn march_descends_at_walls_and_eventually_breaches() {
        let mut cfg = ShooterConfig::space_invaders();
        cfg.horizon = 100_000; // let the breach happen
        cfg.waves = 1;
        let mut g = ShooterGame::new(cfg, 4);
        let mut n = 0u32;
        while !g.is_terminal() {
            g.step(3); // do nothing: the wave must reach the bottom
            n += 1;
            assert!(n < 10_000, "wave must breach in bounded time");
        }
        assert!(g.breached);
        assert!(g.score < 0.0, "breach penalty applied");
    }

    #[test]
    fn clearing_all_waves_terminates_cleanly() {
        let mut cfg = ShooterConfig::space_invaders();
        cfg.waves = 1;
        cfg.rows = 1;
        cfg.density = 1.0;
        cfg.enemy_period = 1000; // effectively static
        let mut g = ShooterGame::new(cfg, 5);
        // Sweep: shoot, move right, shoot...
        let mut n = 0;
        while !g.is_terminal() {
            if g.lowest_in_column(g.player_x).is_some() {
                g.step(2);
            } else if g.player_x < g.cfg.width - 1 {
                g.step(1);
            } else {
                g.step(0);
            }
            n += 1;
            assert!(n < 1000);
        }
        assert!(!g.breached);
        assert!(g.score > 0.0);
    }

    #[test]
    fn snapshot_restore_replay() {
        let mut g = ShooterGame::new(ShooterConfig::centipede(), 6);
        for _ in 0..9 {
            g.step(2);
        }
        let snap = g.snapshot();
        let mut h = ShooterGame::new(ShooterConfig::centipede(), 0);
        h.restore(&snap);
        for i in 0..30 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(i % 4), h.step(i % 4));
        }
    }

    #[test]
    fn heuristic_prefers_shooting_when_target_available() {
        let g = ShooterGame::new(ShooterConfig::space_invaders(), 7);
        if g.lowest_in_column(g.player_x).is_some() {
            assert!(g.action_heuristic(2) > g.action_heuristic(3));
        }
    }
}
