//! Synthetic Atari-like environment suite (Section 5.2 substitute).
//!
//! Six hand-written game mechanics parameterized into the paper's 15 named
//! tasks — see DESIGN.md §3 for the substitution argument. All games honor
//! the [`crate::env::Env`] contract: seeded determinism, bit-exact
//! snapshot/restore, bounded horizons, contract-conforming features.

pub mod chase;
pub mod crossing;
pub mod duel;
pub mod paddle;
pub mod racer;
pub mod shooter;
pub mod suite;

pub use suite::{all, make, FIG5_GAMES, GAMES, TABLE5_GAMES};
