//! Paddle mechanic: ball, paddle, brick wall (Breakout / NameThisGame
//! analogue).
//!
//! Integer physics on a W×H field: the ball moves one cell diagonally per
//! step, reflecting off walls, bricks and the paddle. Actions: 0=left
//! 1=right 2=stay. Reward per brick; losing all lives ends the episode.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct PaddleConfig {
    pub name: &'static str,
    pub width: i64,
    pub height: i64,
    pub brick_rows: i64,
    pub paddle_half: i64,
    pub brick_reward: f64,
    pub lives: u32,
    pub horizon: u32,
}

impl PaddleConfig {
    pub fn breakout() -> Self {
        PaddleConfig {
            name: "Breakout",
            width: 12,
            height: 14,
            brick_rows: 4,
            paddle_half: 1,
            brick_reward: 7.0,
            lives: 3,
            horizon: 400,
        }
    }

    pub fn namethisgame() -> Self {
        PaddleConfig {
            name: "NameThisGame",
            width: 16,
            height: 12,
            brick_rows: 3,
            paddle_half: 2,
            brick_reward: 12.0,
            lives: 4,
            horizon: 500,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PaddleGame {
    cfg: PaddleConfig,
    rng: Pcg32,
    bricks: Vec<bool>, // brick_rows * width (row 0 = top)
    ball: (i64, i64),
    vel: (i64, i64),
    paddle_x: i64, // center
    lives: u32,
    step: u32,
    score: f64,
}

impl PaddleGame {
    pub fn new(cfg: PaddleConfig, seed: u64) -> Self {
        let mut g = PaddleGame {
            cfg,
            rng: Pcg32::new(seed),
            bricks: Vec::new(),
            ball: (0, 0),
            vel: (1, 1),
            paddle_x: 0,
            lives: 0,
            step: 0,
            score: 0.0,
        };
        g.reset(seed);
        g
    }

    fn bricks_left(&self) -> usize {
        self.bricks.iter().filter(|&&b| b).count()
    }

    fn serve(&mut self) {
        self.ball = (self.rng.below(self.cfg.width as u32) as i64, self.cfg.height / 2);
        self.vel = (if self.rng.chance(0.5) { 1 } else { -1 }, 1);
    }

    /// Advance the ball one cell with reflections; returns bricks broken
    /// and whether the ball dropped past the paddle.
    fn advance_ball(&mut self) -> (u32, bool) {
        let mut broken = 0;
        let (mut x, mut y) = self.ball;
        let (mut vx, mut vy) = self.vel;
        // Horizontal walls.
        if x + vx < 0 || x + vx >= self.cfg.width {
            vx = -vx;
        }
        // Ceiling.
        if y + vy < 0 {
            vy = -vy;
        }
        let nx = x + vx;
        let mut ny = y + vy;
        // Brick collision (bricks occupy rows 0..brick_rows).
        if ny < self.cfg.brick_rows && ny >= 0 {
            let bi = (ny * self.cfg.width + nx) as usize;
            if self.bricks[bi] {
                self.bricks[bi] = false;
                broken += 1;
                vy = -vy;
                ny = y + vy;
            }
        }
        // Paddle plane is the bottom row.
        let mut dropped = false;
        if ny >= self.cfg.height - 1 {
            if (nx - self.paddle_x).abs() <= self.cfg.paddle_half {
                vy = -1;
                // English: hitting with the paddle edge deflects.
                if nx < self.paddle_x {
                    vx = -1;
                } else if nx > self.paddle_x {
                    vx = 1;
                }
                ny = self.cfg.height - 2;
            } else {
                dropped = true;
            }
        }
        x = nx.clamp(0, self.cfg.width - 1);
        y = ny.clamp(0, self.cfg.height - 1);
        self.ball = (x, y);
        self.vel = (vx, vy);
        (broken, dropped)
    }
}

impl Env for PaddleGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        let (s, inc) = self.rng.state_and_inc();
        w.u64(s);
        w.u64(inc);
        let bytes: Vec<u8> = self.bricks.iter().map(|&b| b as u8).collect();
        w.bytes(&bytes);
        w.i64(self.ball.0);
        w.i64(self.ball.1);
        w.i64(self.vel.0);
        w.i64(self.vel.1);
        w.i64(self.paddle_x);
        w.u32(self.lives);
        w.u32(self.step);
        w.f64(self.score);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.rng = Pcg32::from_state_and_inc(r.u64(), r.u64());
        self.bricks = r.bytes().iter().map(|&b| b != 0).collect();
        self.ball = (r.i64(), r.i64());
        self.vel = (r.i64(), r.i64());
        self.paddle_x = r.i64();
        self.lives = r.u32();
        self.step = r.u32();
        self.score = r.f64();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0xba11);
        self.bricks = vec![true; (self.cfg.brick_rows * self.cfg.width) as usize];
        self.paddle_x = self.cfg.width / 2;
        self.lives = self.cfg.lives;
        self.step = 0;
        self.score = 0.0;
        self.serve();
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal paddle state");
        assert!(action < 3, "paddle action {action} out of range");
        match action {
            0 => self.paddle_x = (self.paddle_x - 1).max(self.cfg.paddle_half),
            1 => self.paddle_x = (self.paddle_x + 1).min(self.cfg.width - 1 - self.cfg.paddle_half),
            _ => {}
        }
        let (broken, dropped) = self.advance_ball();
        let mut reward = broken as f64 * self.cfg.brick_reward;
        if dropped {
            self.lives -= 1;
            reward -= 5.0;
            if self.lives > 0 {
                self.serve();
            }
        }
        self.step += 1;
        self.score += reward;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        vec![0, 1, 2]
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn is_terminal(&self) -> bool {
        self.lives == 0 || self.step >= self.cfg.horizon || self.bricks_left() == 0
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        if action >= 3 {
            return 0.0;
        }
        // Track the ball's x: prefer the move that closes the gap by the
        // time the ball reaches the paddle plane.
        let target = self.ball.0 + self.vel.0 * (self.cfg.height - 1 - self.ball.1).max(0);
        let target = target.clamp(0, self.cfg.width - 1);
        let next_x = match action {
            0 => self.paddle_x - 1,
            1 => self.paddle_x + 1,
            _ => self.paddle_x,
        };
        let gap_now = (self.paddle_x - target).abs();
        let gap_next = (next_x - target).abs();
        if gap_next < gap_now {
            0.9
        } else if gap_next == gap_now {
            0.5
        } else {
            0.1
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.cfg.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        let total = (self.cfg.brick_rows * self.cfg.width) as f64;
        let cleared = total - self.bricks_left() as f64;
        let lives_frac = self.lives as f64 / self.cfg.lives as f64;
        (cleared / total * 0.7 + lives_frac * 0.3 - 0.3).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        if out.len() < 7 {
            return;
        }
        out[0] = self.ball.0 as f32 / self.cfg.width as f32;
        out[1] = self.ball.1 as f32 / self.cfg.height as f32;
        out[2] = (self.vel.0 as f32 + 1.0) / 2.0;
        out[3] = (self.vel.1 as f32 + 1.0) / 2.0;
        out[4] = self.paddle_x as f32 / self.cfg.width as f32;
        out[5] = self.lives as f32 / self.cfg.lives as f32;
        out[6] = self.bricks_left() as f32 / (self.cfg.brick_rows * self.cfg.width) as f32;
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        self.cfg.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_full_wall_and_lives() {
        let g = PaddleGame::new(PaddleConfig::breakout(), 1);
        assert_eq!(g.bricks_left() as i64, g.cfg.brick_rows * g.cfg.width);
        assert_eq!(g.lives, g.cfg.lives);
        assert!(!g.is_terminal());
    }

    #[test]
    fn paddle_stays_in_bounds() {
        let mut g = PaddleGame::new(PaddleConfig::breakout(), 2);
        for _ in 0..30 {
            if g.is_terminal() {
                break;
            }
            g.step(0);
            assert!(g.paddle_x - g.cfg.paddle_half >= 0);
        }
        let mut g = PaddleGame::new(PaddleConfig::breakout(), 2);
        for _ in 0..30 {
            if g.is_terminal() {
                break;
            }
            g.step(1);
            assert!(g.paddle_x + g.cfg.paddle_half < g.cfg.width);
        }
    }

    #[test]
    fn episode_terminates() {
        let mut g = PaddleGame::new(PaddleConfig::breakout(), 3);
        let mut n = 0;
        while !g.is_terminal() {
            g.step(2); // never move: will eventually drop all lives
            n += 1;
            assert!(n <= g.cfg.horizon);
        }
    }

    #[test]
    fn ball_stays_in_field() {
        let mut g = PaddleGame::new(PaddleConfig::namethisgame(), 4);
        for i in 0..200 {
            if g.is_terminal() {
                break;
            }
            g.step(i % 3);
            assert!((0..g.cfg.width).contains(&g.ball.0), "x {:?}", g.ball);
            assert!((0..g.cfg.height).contains(&g.ball.1), "y {:?}", g.ball);
        }
    }

    #[test]
    fn tracking_heuristic_beats_static_play() {
        let run = |track: bool, seed| {
            let mut g = PaddleGame::new(PaddleConfig::breakout(), seed);
            while !g.is_terminal() {
                let a = if track {
                    (0..3)
                        .max_by(|&a, &b| {
                            g.action_heuristic(a)
                                .partial_cmp(&g.action_heuristic(b))
                                .unwrap()
                        })
                        .unwrap()
                } else {
                    2
                };
                g.step(a);
            }
            g.score
        };
        let tracked: f64 = (0..8).map(|s| run(true, s)).sum();
        let stay: f64 = (0..8).map(|s| run(false, s)).sum();
        assert!(tracked > stay, "tracking {tracked} vs static {stay}");
    }

    #[test]
    fn snapshot_restore_replay() {
        let mut g = PaddleGame::new(PaddleConfig::breakout(), 5);
        for _ in 0..7 {
            g.step(1);
        }
        let snap = g.snapshot();
        let mut h = PaddleGame::new(PaddleConfig::breakout(), 99);
        h.restore(&snap);
        for i in 0..20 {
            if g.is_terminal() {
                break;
            }
            assert_eq!(g.step(i % 3), h.step(i % 3));
        }
    }

    #[test]
    fn breaking_all_bricks_ends_episode() {
        let mut g = PaddleGame::new(PaddleConfig::breakout(), 6);
        g.bricks.iter_mut().for_each(|b| *b = false);
        assert!(g.is_terminal());
    }
}
