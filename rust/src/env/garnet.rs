//! GARNET-style random MDPs: tiny, fully-specified environments used by
//! property tests to exercise search-algorithm invariants on arbitrary
//! transition structures (Generated Average Reward Non-stationary
//! Environment Testbench; Archibald et al., 1995).
//!
//! The MDP is drawn once from a seed: `n_states` states, `n_actions`
//! actions, each (s, a) pair transitioning deterministically to a random
//! state with a random reward in [0, 1]; a configurable fraction of states
//! is terminal. Small enough that exact value iteration is feasible, which
//! the tests use as ground truth for search quality.

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult};
use crate::util::rng::Pcg32;

/// A randomly generated deterministic MDP.
#[derive(Debug, Clone)]
pub struct Garnet {
    pub n_states: usize,
    n_actions: usize,
    /// next[s * n_actions + a]
    next: Vec<usize>,
    /// reward[s * n_actions + a]
    reward: Vec<f64>,
    terminal: Vec<bool>,
    horizon: u32,
    state: usize,
    step: u32,
}

impl Garnet {
    /// Draw an MDP from `seed`. `p_terminal` states are absorbing.
    pub fn new(n_states: usize, n_actions: usize, horizon: u32, p_terminal: f64, seed: u64) -> Self {
        assert!(n_states >= 2 && n_actions >= 1);
        let mut rng = Pcg32::new(seed ^ 0x6a27);
        let next: Vec<usize> = (0..n_states * n_actions)
            .map(|_| rng.below_usize(n_states))
            .collect();
        let reward: Vec<f64> = (0..n_states * n_actions).map(|_| rng.next_f64()).collect();
        let mut terminal: Vec<bool> = (0..n_states).map(|_| rng.chance(p_terminal)).collect();
        terminal[0] = false; // the start state is never terminal
        Garnet {
            n_states,
            n_actions,
            next,
            reward,
            terminal,
            horizon,
            state: 0,
            step: 0,
        }
    }

    pub fn current_state(&self) -> usize {
        self.state
    }

    /// Exact Q*(current state, action) with `depth` steps to go —
    /// ground truth for "did the search pick a near-best arm" tests.
    pub fn q_star(&self, action: usize, depth: u32) -> f64 {
        let i = self.state * self.n_actions + action;
        self.reward[i] + self.optimal_value(self.next[i], depth.saturating_sub(1))
    }

    /// Exact optimal value of `state` with `depth` steps to go (finite-
    /// horizon value iteration) — ground truth for search-quality tests.
    pub fn optimal_value(&self, state: usize, depth: u32) -> f64 {
        if depth == 0 || self.terminal[state] {
            return 0.0;
        }
        (0..self.n_actions)
            .map(|a| {
                let i = state * self.n_actions + a;
                self.reward[i] + self.optimal_value(self.next[i], depth - 1)
            })
            .fold(f64::MIN, f64::max)
    }
}

impl Env for Garnet {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        w.u32(self.state as u32);
        w.u32(self.step);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        self.state = r.u32() as usize;
        self.step = r.u32();
        debug_assert!(r.exhausted());
    }

    fn reset(&mut self, _seed: u64) {
        // The MDP itself is fixed at construction; reset returns to s0.
        self.state = 0;
        self.step = 0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal garnet state");
        assert!(action < self.n_actions, "garnet action out of range");
        let i = self.state * self.n_actions + action;
        let reward = self.reward[i];
        self.state = self.next[i];
        self.step += 1;
        StepResult { reward, done: self.is_terminal() }
    }

    fn legal_actions(&self) -> Vec<usize> {
        (0..self.n_actions).collect()
    }

    fn num_actions(&self) -> usize {
        self.n_actions
    }

    fn is_terminal(&self) -> bool {
        self.terminal[self.state] || self.step >= self.horizon
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        // One-step greedy signal: the immediate reward.
        if action < self.n_actions {
            self.reward[self.state * self.n_actions + action]
        } else {
            0.0
        }
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.step as f64 / self.horizon as f64
    }

    fn heuristic_value(&self) -> f64 {
        // Average available reward, roughly centered.
        let base = self.state * self.n_actions;
        let avg: f64 = (0..self.n_actions)
            .map(|a| self.reward[base + a])
            .sum::<f64>()
            / self.n_actions as f64;
        (avg * 2.0 - 1.0).clamp(-1.0, 1.0)
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        "garnet"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_deterministic() {
        let a = Garnet::new(10, 3, 20, 0.1, 5);
        let b = Garnet::new(10, 3, 20, 0.1, 5);
        assert_eq!(a.next, b.next);
        assert_eq!(a.reward, b.reward);
    }

    #[test]
    fn start_state_playable() {
        let g = Garnet::new(8, 2, 10, 0.5, 1);
        assert!(!g.is_terminal());
        assert_eq!(g.legal_actions(), vec![0, 1]);
    }

    #[test]
    fn optimal_value_monotone_in_depth() {
        let g = Garnet::new(12, 3, 20, 0.0, 7);
        // Rewards are >= 0, so deeper horizons cannot decrease the optimum.
        let v1 = g.optimal_value(0, 1);
        let v3 = g.optimal_value(0, 3);
        let v6 = g.optimal_value(0, 6);
        assert!(v3 >= v1 - 1e-12);
        assert!(v6 >= v3 - 1e-12);
    }

    #[test]
    fn greedy_play_bounded_by_optimal() {
        let mut g = Garnet::new(10, 3, 8, 0.0, 3);
        let opt = g.optimal_value(0, 8);
        let mut total = 0.0;
        while !g.is_terminal() {
            // one-step greedy
            let a = (0..3)
                .max_by(|&x, &y| {
                    g.action_heuristic(x)
                        .partial_cmp(&g.action_heuristic(y))
                        .unwrap()
                })
                .unwrap();
            total += g.step(a).reward;
        }
        assert!(total <= opt + 1e-9, "greedy {total} cannot beat optimal {opt}");
    }

    #[test]
    fn snapshot_restore() {
        let mut g = Garnet::new(10, 3, 20, 0.2, 9);
        g.step(1);
        g.step(2);
        let snap = g.snapshot();
        let mut h = g.clone();
        h.step(0);
        h.restore(&snap);
        assert_eq!(h.current_state(), g.current_state());
    }

    #[test]
    fn horizon_terminates() {
        let mut g = Garnet::new(5, 2, 6, 0.0, 11);
        let mut n = 0;
        while !g.is_terminal() {
            g.step(0);
            n += 1;
        }
        assert!(n <= 6);
    }
}
