//! Environment substrate: the MDP interface every search algorithm runs on.
//!
//! The paper's MCTS interacts with an *environment emulator* whose states
//! can be cloned, stored in the master's centralized game-state buffer and
//! shipped to expansion / simulation workers. [`Env`] captures exactly that
//! contract: deterministic-given-state transitions (footnote 2 of the
//! paper), clone-able snapshots, and a fixed-width feature encoding shared
//! with the L1/L2 network (see `python/compile/model.py`).
//!
//! Concrete environments:
//! * [`tapgame`] — the "Joy City" tap-elimination game (Appendix C.1);
//! * [`atari`] — 15 synthetic Atari-like tasks (Section 5.2 substitute);
//! * [`garnet`] — random MDPs for property tests.

pub mod atari;
pub mod garnet;
pub mod latency;
pub mod tapgame;

pub use latency::SlowEnv;

/// Feature-vector contract (keep in sync with python/compile/model.py).
pub const FEATURE_DIM: usize = 128;
/// Maximum action-space size across environments.
pub const MAX_ACTIONS: usize = 16;
/// f[0..A): per-action heuristic scores; f[A..2A): legality mask.
pub const FEAT_MASK_OFFSET: usize = MAX_ACTIONS;
/// f[2A]: remaining-step fraction.
pub const FEAT_FRAC_INDEX: usize = 2 * MAX_ACTIONS;
/// f[2A+1]: heuristic state-value estimate in [-1, 1].
pub const FEAT_VALUE_INDEX: usize = 2 * MAX_ACTIONS + 1;
/// f[2A+2..): env-specific summary features.
pub const FEAT_SUMMARY_OFFSET: usize = 2 * MAX_ACTIONS + 2;

/// Result of one environment step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepResult {
    pub reward: f64,
    pub done: bool,
}

/// A clonable, seedable MDP emulator.
///
/// Implementations must be `Send + Sync` (boxed emulators travel to
/// worker threads; shared references cross scoped-thread boundaries) and
/// deterministic as a function of (snapshot, action, internal rng state):
/// restoring a snapshot and replaying the same actions must reproduce the
/// same trajectory bit-for-bit.
pub trait Env: Send + Sync {
    /// Opaque snapshot of the full state (including rng state).
    fn snapshot(&self) -> EnvState;

    /// Restore a snapshot previously produced by `snapshot`.
    fn restore(&mut self, state: &EnvState);

    /// Reset to the initial state for `seed`.
    fn reset(&mut self, seed: u64);

    /// Apply `action`; panics if called on a terminal state or with an
    /// illegal action (callers must consult [`Env::legal_actions`]).
    fn step(&mut self, action: usize) -> StepResult;

    /// Legal actions at the current state (indices < [`Env::num_actions`]).
    fn legal_actions(&self) -> Vec<usize>;

    /// Size of this environment's action space (≤ [`MAX_ACTIONS`]).
    fn num_actions(&self) -> usize;

    /// Whether the current state is terminal.
    fn is_terminal(&self) -> bool;

    /// Fill `out` (len [`FEATURE_DIM`]) according to the feature contract.
    /// The default implementation composes the per-action heuristics,
    /// legality mask, step fraction, heuristic value and summary features.
    fn features(&self, out: &mut [f32]) {
        assert_eq!(out.len(), FEATURE_DIM);
        out.fill(0.0);
        let legal = self.legal_actions();
        for &a in &legal {
            out[a] = self.action_heuristic(a) as f32;
            out[FEAT_MASK_OFFSET + a] = 1.0;
        }
        out[FEAT_FRAC_INDEX] = self.remaining_fraction() as f32;
        out[FEAT_VALUE_INDEX] = self.heuristic_value().clamp(-1.0, 1.0) as f32;
        self.summary_features(&mut out[FEAT_SUMMARY_OFFSET..]);
    }

    /// One-step heuristic desirability of `action`, roughly in [0, 1].
    fn action_heuristic(&self, action: usize) -> f64;

    /// Fraction of the step budget remaining in [0, 1].
    fn remaining_fraction(&self) -> f64;

    /// Cheap heuristic estimate of the state value in [-1, 1].
    fn heuristic_value(&self) -> f64;

    /// Env-specific summary features (may leave zeros).
    fn summary_features(&self, _out: &mut [f32]) {}

    /// Clone the emulator into a boxed instance (worker fan-out).
    fn clone_boxed(&self) -> Box<dyn Env>;

    /// Short environment name for tables.
    fn name(&self) -> &str;
}

/// Serialized environment snapshot.
///
/// Stored in the WU-UCT master's centralized game-state buffer; the paper's
/// Appendix A argues each state is used at most |A| + 1 times, making the
/// centralized store the efficient choice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnvState(pub Vec<u8>);

impl EnvState {
    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// Byte-level state (de)serialization helpers shared by env impls.
pub mod codec {
    /// Growable little-endian writer.
    #[derive(Default)]
    pub struct Writer(Vec<u8>);

    impl Writer {
        pub fn new() -> Self {
            Self::default()
        }
        pub fn u8(&mut self, v: u8) {
            self.0.push(v);
        }
        pub fn u16(&mut self, v: u16) {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        pub fn u32(&mut self, v: u32) {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        pub fn u64(&mut self, v: u64) {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        pub fn i64(&mut self, v: i64) {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        pub fn f64(&mut self, v: f64) {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        pub fn bytes(&mut self, v: &[u8]) {
            self.u32(v.len() as u32);
            self.0.extend_from_slice(v);
        }
        pub fn finish(self) -> Vec<u8> {
            self.0
        }
    }

    /// Cursor-based reader; panics on underrun (snapshots are trusted,
    /// produced by the paired Writer).
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Self { buf, pos: 0 }
        }
        fn take(&mut self, n: usize) -> &'a [u8] {
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            s
        }
        pub fn u8(&mut self) -> u8 {
            self.take(1)[0]
        }
        pub fn u16(&mut self) -> u16 {
            u16::from_le_bytes(self.take(2).try_into().unwrap())
        }
        pub fn u32(&mut self) -> u32 {
            u32::from_le_bytes(self.take(4).try_into().unwrap())
        }
        pub fn u64(&mut self) -> u64 {
            u64::from_le_bytes(self.take(8).try_into().unwrap())
        }
        pub fn i64(&mut self) -> i64 {
            i64::from_le_bytes(self.take(8).try_into().unwrap())
        }
        pub fn f64(&mut self) -> f64 {
            f64::from_le_bytes(self.take(8).try_into().unwrap())
        }
        pub fn bytes(&mut self) -> &'a [u8] {
            let n = self.u32() as usize;
            self.take(n)
        }
        pub fn exhausted(&self) -> bool {
            self.pos == self.buf.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::codec::{Reader, Writer};

    #[test]
    fn codec_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(65535);
        w.u32(123456);
        w.u64(u64::MAX);
        w.i64(-42);
        w.f64(3.5);
        w.bytes(b"hello");
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8(), 7);
        assert_eq!(r.u16(), 65535);
        assert_eq!(r.u32(), 123456);
        assert_eq!(r.u64(), u64::MAX);
        assert_eq!(r.i64(), -42);
        assert_eq!(r.f64(), 3.5);
        assert_eq!(r.bytes(), b"hello");
        assert!(r.exhausted());
    }

    #[test]
    fn feature_layout_constants_consistent() {
        use super::*;
        assert!(FEAT_SUMMARY_OFFSET < FEATURE_DIM);
        assert_eq!(FEAT_FRAC_INDEX, 32);
        assert_eq!(FEAT_VALUE_INDEX, 33);
    }
}
