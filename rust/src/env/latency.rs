//! Latency-simulated emulator wrapper (see DESIGN.md §3).
//!
//! The paper's expansion/simulation steps are slow because every rollout
//! step runs the game emulator; its testbed parallelized that across 16+
//! cores. This container exposes one core, so [`SlowEnv`] reintroduces the
//! emulator cost as *wall-clock latency* (`thread::sleep` per step), which
//! overlaps across worker threads exactly the way per-core emulator work
//! overlapped in the paper. All speedup experiments (Fig. 4, Table 3,
//! Fig. 5's time axis, Fig. 2) run on `SlowEnv`-wrapped environments;
//! quality experiments use the raw fast envs.

use std::time::Duration;

use crate::env::{Env, EnvState, StepResult};

/// Wraps an environment, adding fixed per-`step` latency.
pub struct SlowEnv {
    inner: Box<dyn Env>,
    delay: Duration,
}

impl SlowEnv {
    pub fn new(inner: Box<dyn Env>, delay: Duration) -> SlowEnv {
        SlowEnv { inner, delay }
    }

    /// The paper's per-step emulator cost, scaled for bench runtimes.
    pub fn default_delay() -> Duration {
        Duration::from_micros(150)
    }
}

impl Env for SlowEnv {
    fn snapshot(&self) -> EnvState {
        self.inner.snapshot()
    }

    fn restore(&mut self, state: &EnvState) {
        self.inner.restore(state)
    }

    fn reset(&mut self, seed: u64) {
        self.inner.reset(seed)
    }

    fn step(&mut self, action: usize) -> StepResult {
        // The emulator latency: dominated by wall time, not CPU, exactly
        // like a reserved-core emulator from the master's point of view.
        std::thread::sleep(self.delay);
        self.inner.step(action)
    }

    fn legal_actions(&self) -> Vec<usize> {
        self.inner.legal_actions()
    }

    fn num_actions(&self) -> usize {
        self.inner.num_actions()
    }

    fn is_terminal(&self) -> bool {
        self.inner.is_terminal()
    }

    fn features(&self, out: &mut [f32]) {
        self.inner.features(out)
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        self.inner.action_heuristic(action)
    }

    fn remaining_fraction(&self) -> f64 {
        self.inner.remaining_fraction()
    }

    fn heuristic_value(&self) -> f64 {
        self.inner.heuristic_value()
    }

    fn summary_features(&self, out: &mut [f32]) {
        self.inner.summary_features(out)
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(SlowEnv { inner: self.inner.clone_boxed(), delay: self.delay })
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;
    use std::time::Instant;

    fn slow(delay_us: u64) -> SlowEnv {
        SlowEnv::new(
            Box::new(Garnet::new(10, 3, 100, 0.0, 1)),
            Duration::from_micros(delay_us),
        )
    }

    #[test]
    fn behaves_identically_to_inner() {
        let mut fast = Garnet::new(10, 3, 100, 0.0, 1);
        let mut s = slow(0);
        for i in 0..20 {
            let a = i % 3;
            assert_eq!(fast.step(a), s.step(a));
            assert_eq!(fast.is_terminal(), s.is_terminal());
        }
        assert_eq!(fast.snapshot(), s.snapshot());
    }

    #[test]
    fn step_incurs_latency() {
        let mut s = slow(2000);
        let t = Instant::now();
        for _ in 0..5 {
            s.step(0);
        }
        assert!(t.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn sleeping_steps_overlap_across_threads() {
        // The core property the speedup experiments rely on: two threads
        // sleeping concurrently take ~1x, not 2x, wall time — even on a
        // single CPU core.
        let run = |threads: usize| {
            let t = Instant::now();
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        let mut e = slow(1500);
                        for _ in 0..10 {
                            e.step(0);
                        }
                    });
                }
            });
            t.elapsed()
        };
        let one = run(1);
        let four = run(4);
        assert!(
            four < one * 2,
            "4 threads {four:?} should overlap (1 thread {one:?})"
        );
    }

    #[test]
    fn clone_preserves_delay() {
        let s = slow(2000);
        let mut c = s.clone_boxed();
        let t = Instant::now();
        c.step(0);
        assert!(t.elapsed() >= Duration::from_micros(1800));
    }
}
