//! The 9×9 tap-elimination board: flood-fill regions, elimination, props,
//! gravity, stochastic refill.
//!
//! Mechanics reproduce Appendix C.1's description: tapping a same-color
//! connected region (size ≥ 2) eliminates it; balloons adjacent to an
//! eliminated cell pop; cats fall with gravity and are collected at the
//! bottom row; big taps (≥ prop threshold) award a rocket that clears the
//! tapped row; eliminated cells collapse downward and columns refill from
//! the top with random colors (and occasional balloons).

use crate::util::rng::Pcg32;

pub const SIZE: usize = 9;
pub const CELLS: usize = SIZE * SIZE;

/// Cell encoding inside the raw grid.
pub const EMPTY: u8 = 255;
pub const BALLOON: u8 = 200;
pub const CAT: u8 = 201;

/// Outcome of one tap.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TapOutcome {
    pub eliminated: u32,
    pub balloons_popped: u32,
    pub cats_collected: u32,
    pub prop_triggered: bool,
}

/// A same-color connected region, the unit of tapping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    /// Cell indices (row-major), sorted ascending.
    pub cells: Vec<usize>,
    /// Color of every cell in the region.
    pub color: u8,
}

impl Region {
    pub fn size(&self) -> usize {
        self.cells.len()
    }
    /// Deterministic anchor: smallest cell index.
    pub fn anchor(&self) -> usize {
        self.cells[0]
    }
}

/// The mutable board grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Board {
    grid: [u8; CELLS],
}

#[inline]
fn rc(idx: usize) -> (usize, usize) {
    (idx / SIZE, idx % SIZE)
}

#[inline]
fn idx(row: usize, col: usize) -> usize {
    row * SIZE + col
}

impl Board {
    /// Fresh board: random colors everywhere, then `cats` cats placed in
    /// the top rows (they must fall to be collected).
    pub fn random(rng: &mut Pcg32, colors: u8, cats: u32, p_balloon: f64) -> Board {
        let mut grid = [EMPTY; CELLS];
        for cell in grid.iter_mut() {
            *cell = if rng.chance(p_balloon) {
                BALLOON
            } else {
                rng.below(colors as u32) as u8
            };
        }
        let mut board = Board { grid };
        // Place cats in the top two rows at distinct random columns.
        let mut cols: Vec<usize> = (0..SIZE).collect();
        rng.shuffle(&mut cols);
        for (i, &col) in cols.iter().take(cats as usize).enumerate() {
            board.grid[idx(i / SIZE, col)] = CAT;
        }
        board
    }

    pub fn get(&self, row: usize, col: usize) -> u8 {
        self.grid[idx(row, col)]
    }

    pub fn raw(&self) -> &[u8; CELLS] {
        &self.grid
    }

    pub fn from_raw(grid: [u8; CELLS]) -> Board {
        Board { grid }
    }

    fn is_color(v: u8) -> bool {
        v < BALLOON
    }

    /// All tappable regions (same-color, orthogonally connected, size ≥ 2),
    /// sorted by size descending then anchor ascending — the deterministic
    /// ordering that defines the action space.
    pub fn regions(&self) -> Vec<Region> {
        let mut seen = [false; CELLS];
        let mut regions = Vec::new();
        for start in 0..CELLS {
            if seen[start] || !Self::is_color(self.grid[start]) {
                continue;
            }
            let color = self.grid[start];
            // Iterative flood fill.
            let mut stack = vec![start];
            let mut cells = Vec::new();
            seen[start] = true;
            while let Some(cur) = stack.pop() {
                cells.push(cur);
                let (r, c) = rc(cur);
                let push = |nr: usize, nc: usize, stack: &mut Vec<usize>, seen: &mut [bool; CELLS]| {
                    let ni = idx(nr, nc);
                    if !seen[ni] && self.grid[ni] == color {
                        seen[ni] = true;
                        stack.push(ni);
                    }
                };
                if r > 0 {
                    push(r - 1, c, &mut stack, &mut seen);
                }
                if r + 1 < SIZE {
                    push(r + 1, c, &mut stack, &mut seen);
                }
                if c > 0 {
                    push(r, c - 1, &mut stack, &mut seen);
                }
                if c + 1 < SIZE {
                    push(r, c + 1, &mut stack, &mut seen);
                }
            }
            if cells.len() >= 2 {
                cells.sort_unstable();
                regions.push(Region { cells, color });
            }
        }
        regions.sort_by(|a, b| b.size().cmp(&a.size()).then(a.anchor().cmp(&b.anchor())));
        regions
    }

    /// Count of balloons orthogonally adjacent to the region (a cheap
    /// heuristic signal; popping them is what `tap` actually does).
    pub fn adjacent_balloons(&self, region: &Region) -> u32 {
        let mut marked = [false; CELLS];
        for &cell in &region.cells {
            let (r, c) = rc(cell);
            let mark = |nr: usize, nc: usize, marked: &mut [bool; CELLS]| {
                let ni = idx(nr, nc);
                if self.grid[ni] == BALLOON {
                    marked[ni] = true;
                }
            };
            if r > 0 {
                mark(r - 1, c, &mut marked);
            }
            if r + 1 < SIZE {
                mark(r + 1, c, &mut marked);
            }
            if c > 0 {
                mark(r, c - 1, &mut marked);
            }
            if c + 1 < SIZE {
                mark(r, c + 1, &mut marked);
            }
        }
        marked.iter().filter(|&&m| m).count() as u32
    }

    /// Count of cats orthogonally adjacent to the region (rescue targets).
    pub fn adjacent_cats(&self, region: &Region) -> u32 {
        let mut marked = [false; CELLS];
        for &cell in &region.cells {
            let (r, c) = rc(cell);
            let mark = |nr: usize, nc: usize, marked: &mut [bool; CELLS]| {
                let ni = idx(nr, nc);
                if self.grid[ni] == CAT {
                    marked[ni] = true;
                }
            };
            if r > 0 {
                mark(r - 1, c, &mut marked);
            }
            if r + 1 < SIZE {
                mark(r + 1, c, &mut marked);
            }
            if c > 0 {
                mark(r, c - 1, &mut marked);
            }
            if c + 1 < SIZE {
                mark(r, c + 1, &mut marked);
            }
        }
        marked.iter().filter(|&&m| m).count() as u32
    }

    /// Execute a tap on `region`: eliminate it (plus the anchor row when the
    /// rocket prop triggers), pop adjacent balloons, apply gravity, refill,
    /// and collect bottom-row cats. Deterministic given `rng` state.
    pub fn tap(
        &mut self,
        region: &Region,
        colors: u8,
        p_balloon: f64,
        prop_threshold: usize,
        rng: &mut Pcg32,
    ) -> TapOutcome {
        let mut out = TapOutcome::default();
        let mut kill = [false; CELLS];
        for &cell in &region.cells {
            kill[cell] = true;
        }
        // Rocket prop: clear the anchor's whole row.
        if region.size() >= prop_threshold {
            out.prop_triggered = true;
            let (row, _) = rc(region.anchor());
            for col in 0..SIZE {
                let i = idx(row, col);
                if self.grid[i] != EMPTY && self.grid[i] != CAT {
                    kill[i] = true;
                }
            }
        }
        // Pop balloons — and rescue cats — adjacent to anything killed
        // (Appendix C.1: "when some cell is exploded beside a balloon, it
        // will also explode"; cats react the same way or are collected by
        // falling to the bottom row).
        let mut pop = [false; CELLS];
        for cell in 0..CELLS {
            if !kill[cell] {
                continue;
            }
            let (r, c) = rc(cell);
            let mark = |nr: usize, nc: usize, pop: &mut [bool; CELLS]| {
                let ni = idx(nr, nc);
                if self.grid[ni] == BALLOON || self.grid[ni] == CAT {
                    pop[ni] = true;
                }
            };
            if r > 0 {
                mark(r - 1, c, &mut pop);
            }
            if r + 1 < SIZE {
                mark(r + 1, c, &mut pop);
            }
            if c > 0 {
                mark(r, c - 1, &mut pop);
            }
            if c + 1 < SIZE {
                mark(r, c + 1, &mut pop);
            }
        }
        for cell in 0..CELLS {
            if kill[cell] && self.grid[cell] == BALLOON {
                // A balloon caught in a rocket row also pops.
                pop[cell] = true;
            }
            if pop[cell] {
                if self.grid[cell] == CAT {
                    out.cats_collected += 1;
                } else {
                    out.balloons_popped += 1;
                }
                self.grid[cell] = EMPTY;
            } else if kill[cell] {
                out.eliminated += 1;
                self.grid[cell] = EMPTY;
            }
        }
        // Gravity + refill, column by column.
        for col in 0..SIZE {
            let mut write = SIZE; // next row to fill, from the bottom
            for row in (0..SIZE).rev() {
                let i = idx(row, col);
                if self.grid[i] != EMPTY {
                    write -= 1;
                    let j = idx(write, col);
                    if j != i {
                        self.grid[j] = self.grid[i];
                        self.grid[i] = EMPTY;
                    }
                }
            }
            for row in 0..write {
                let i = idx(row, col);
                self.grid[i] = if rng.chance(p_balloon) {
                    BALLOON
                } else {
                    rng.below(colors as u32) as u8
                };
            }
        }
        // Collect cats that reached the bottom row.
        for col in 0..SIZE {
            let i = idx(SIZE - 1, col);
            if self.grid[i] == CAT {
                out.cats_collected += 1;
                self.grid[i] = EMPTY;
            }
        }
        // Cats removed from the bottom leave holes; settle once more
        // (no refill needed at top for these single holes — next refill
        // pass will handle them; we refill immediately for invariant:
        // the grid never contains EMPTY between taps).
        if out.cats_collected > 0 {
            for col in 0..SIZE {
                let mut write = SIZE;
                for row in (0..SIZE).rev() {
                    let i = idx(row, col);
                    if self.grid[i] != EMPTY {
                        write -= 1;
                        let j = idx(write, col);
                        if j != i {
                            self.grid[j] = self.grid[i];
                            self.grid[i] = EMPTY;
                        }
                    }
                }
                for row in 0..write {
                    self.grid[idx(row, col)] = if rng.chance(p_balloon) {
                        BALLOON
                    } else {
                        rng.below(colors as u32) as u8
                    };
                }
            }
        }
        out
    }

    /// Boss disturbance: recolor one random color-cell.
    pub fn boss_throw(&mut self, colors: u8, rng: &mut Pcg32) {
        for _ in 0..8 {
            let i = rng.below_usize(CELLS);
            if Self::is_color(self.grid[i]) {
                self.grid[i] = rng.below(colors as u32) as u8;
                return;
            }
        }
    }

    /// Number of cats still on the board.
    pub fn cats_on_board(&self) -> u32 {
        self.grid.iter().filter(|&&v| v == CAT).count() as u32
    }

    /// Number of balloons on the board.
    pub fn balloons_on_board(&self) -> u32 {
        self.grid.iter().filter(|&&v| v == BALLOON).count() as u32
    }

    /// Histogram of color frequencies (length = colors), normalized.
    pub fn color_histogram(&self, colors: u8) -> Vec<f32> {
        let mut h = vec![0f32; colors as usize];
        for &v in &self.grid {
            if (v as usize) < h.len() {
                h[v as usize] += 1.0;
            }
        }
        for v in h.iter_mut() {
            *v /= CELLS as f32;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::new(12345)
    }

    #[test]
    fn random_board_has_no_empty_cells() {
        let b = Board::random(&mut rng(), 4, 2, 0.1);
        assert!(b.raw().iter().all(|&v| v != EMPTY));
        assert_eq!(b.cats_on_board(), 2);
    }

    #[test]
    fn regions_are_disjoint_and_sorted() {
        let b = Board::random(&mut rng(), 3, 0, 0.0);
        let regions = b.regions();
        assert!(!regions.is_empty(), "3 colors on 81 cells must connect");
        let mut seen = [false; CELLS];
        for r in &regions {
            assert!(r.size() >= 2);
            for w in r.cells.windows(2) {
                assert!(w[0] < w[1], "cells sorted");
            }
            for &c in &r.cells {
                assert!(!seen[c], "regions must not overlap");
                seen[c] = true;
                assert_eq!(b.raw()[c], r.color);
            }
        }
        for w in regions.windows(2) {
            assert!(w[0].size() >= w[1].size(), "sorted by size desc");
        }
    }

    #[test]
    fn uniform_board_is_one_region() {
        let b = Board::from_raw([1u8; CELLS]);
        let regions = b.regions();
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].size(), CELLS);
    }

    #[test]
    fn checkerboard_has_no_regions() {
        let mut grid = [0u8; CELLS];
        for (i, cell) in grid.iter_mut().enumerate() {
            let (r, c) = super::rc(i);
            *cell = ((r + c) % 2) as u8;
        }
        let b = Board::from_raw(grid);
        assert!(b.regions().is_empty(), "isolated cells are not tappable");
    }

    #[test]
    fn tap_eliminates_and_refills() {
        let mut b = Board::from_raw([2u8; CELLS]);
        let regions = b.regions();
        let mut r = rng();
        let out = b.tap(&regions[0], 4, 0.0, 100, &mut r);
        assert_eq!(out.eliminated, CELLS as u32);
        assert!(!out.prop_triggered, "threshold 100 never triggers");
        // Board fully refilled, no empties.
        assert!(b.raw().iter().all(|&v| v != EMPTY));
    }

    #[test]
    fn tap_pops_adjacent_balloons() {
        // Column 0 all color 1 (a tappable region); a balloon at (0, 1).
        let mut grid = [0u8; CELLS];
        for (i, cell) in grid.iter_mut().enumerate() {
            let (_r, c) = super::rc(i);
            *cell = if c == 0 { 1 } else { (c % 2 + 2) as u8 };
        }
        grid[idx(0, 1)] = BALLOON;
        let mut b = Board::from_raw(grid);
        let regions = b.regions();
        let col0 = regions.iter().find(|r| r.color == 1).expect("column region");
        assert_eq!(b.adjacent_balloons(col0), 1);
        let out = b.tap(col0, 4, 0.0, 100, &mut rng());
        assert_eq!(out.balloons_popped, 1);
    }

    #[test]
    fn prop_rocket_clears_anchor_row() {
        // Two-cell region at the anchor row; threshold 2 triggers the prop.
        let mut grid = [EMPTY; CELLS];
        // Fill deterministic colors, no adjacency except our pair.
        for (i, cell) in grid.iter_mut().enumerate() {
            let (r, c) = super::rc(i);
            *cell = ((r * 3 + c * 5) % 7 % 4) as u8; // pseudo-random-ish
        }
        grid[idx(4, 0)] = 9 % 4; // ensure pair
        grid[idx(4, 1)] = grid[idx(4, 0)];
        // Make sure they're actually equal-color adjacent pair:
        let mut b = Board::from_raw(grid);
        let regions = b.regions();
        let target = regions
            .iter()
            .find(|r| r.cells.contains(&idx(4, 0)) && r.cells.contains(&idx(4, 1)));
        if let Some(region) = target {
            let out = b.tap(region, 4, 0.0, 2, &mut rng());
            assert!(out.prop_triggered);
            assert!(out.eliminated as usize >= SIZE.min(region.size() + 3));
        }
    }

    #[test]
    fn gravity_moves_cat_down() {
        let mut grid = [0u8; CELLS];
        // Alternate colors so the cat column is tappable below it.
        for (i, cell) in grid.iter_mut().enumerate() {
            let (r, _c) = super::rc(i);
            *cell = (r % 2) as u8;
        }
        grid[idx(0, 3)] = CAT;
        // Make the whole column below the cat one region:
        for row in 1..SIZE {
            grid[idx(row, 3)] = 2;
        }
        let mut b = Board::from_raw(grid);
        let regions = b.regions();
        let col = regions.iter().find(|r| r.color == 2).expect("cat column");
        let out = b.tap(col, 4, 0.0, 100, &mut rng());
        // The column below the cat vanished; the cat fell to the bottom row
        // and was collected.
        assert_eq!(out.cats_collected, 1);
        assert_eq!(b.cats_on_board(), 0);
    }

    #[test]
    fn tap_is_deterministic_given_rng_state() {
        let mut b1 = Board::random(&mut Pcg32::new(7), 4, 1, 0.1);
        let mut b2 = b1.clone();
        let r1 = b1.regions();
        let r2 = b2.regions();
        assert_eq!(r1, r2);
        let mut g1 = Pcg32::new(99);
        let mut g2 = Pcg32::new(99);
        let o1 = b1.tap(&r1[0], 4, 0.1, 6, &mut g1);
        let o2 = b2.tap(&r2[0], 4, 0.1, 6, &mut g2);
        assert_eq!(o1, o2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn boss_throw_changes_at_most_one_cell() {
        let mut b = Board::from_raw([1u8; CELLS]);
        let before = *b.raw();
        b.boss_throw(4, &mut rng());
        let diff = before
            .iter()
            .zip(b.raw().iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(diff <= 1);
    }

    #[test]
    fn color_histogram_sums_to_color_fraction() {
        let b = Board::random(&mut rng(), 4, 0, 0.0);
        let h = b.color_histogram(4);
        let total: f32 = h.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "no balloons/cats => mass 1");
    }
}
