//! The tap-elimination game ("Joy City" analogue, Appendix C.1) as an
//! [`Env`].
//!
//! Action space: the up-to-[`MAX_ACTIONS`] largest tappable regions of the
//! current board, in the deterministic order produced by
//! [`board::Board::regions`]. Reward: collected goal units (balloon = 1,
//! cat = 3) normalized by the level's total goal units, plus a +1 pass
//! bonus; an episode ends on pass or when the tap budget runs out. The
//! number of taps used ("game steps") is the paper's Section-5.1 metric.

pub mod board;
pub mod level;

use crate::env::codec::{Reader, Writer};
use crate::env::{Env, EnvState, StepResult, MAX_ACTIONS};
use crate::util::rng::Pcg32;

use board::{Board, Region, CELLS};
pub use level::{Level, LevelGen};

/// Tap-game environment.
#[derive(Debug, Clone)]
pub struct TapGame {
    level: Level,
    board: Board,
    rng: Pcg32,
    steps_used: u32,
    balloons: u32,
    cats: u32,
    passed: bool,
    /// Cached tappable regions of the current board (the action space).
    actions: Vec<Region>,
}

impl TapGame {
    pub fn new(level: Level, seed: u64) -> TapGame {
        let mut game = TapGame {
            level,
            board: Board::from_raw([0; CELLS]),
            rng: Pcg32::new(seed),
            steps_used: 0,
            balloons: 0,
            cats: 0,
            passed: false,
            actions: Vec::new(),
        };
        game.reset(seed);
        game
    }

    fn refresh_actions(&mut self) {
        let mut regions = self.board.regions();
        regions.truncate(MAX_ACTIONS);
        self.actions = regions;
    }

    /// Did the episode end with all goals completed?
    pub fn passed(&self) -> bool {
        self.passed
    }

    /// Taps consumed so far (the "game step" metric).
    pub fn steps_used(&self) -> u32 {
        self.steps_used
    }

    pub fn level(&self) -> &Level {
        &self.level
    }

    /// Fraction of goal units collected, in [0, 1].
    pub fn progress(&self) -> f64 {
        let units = self.balloons.min(self.level.goal_balloons) as f64
            + 3.0 * self.cats.min(self.level.goal_cats) as f64;
        (units / self.level.goal_units()).min(1.0)
    }

    fn goals_done(&self) -> bool {
        self.balloons >= self.level.goal_balloons && self.cats >= self.level.goal_cats
    }
}

impl Env for TapGame {
    fn snapshot(&self) -> EnvState {
        let mut w = Writer::new();
        w.bytes(self.board.raw());
        // rng state: serialize via two u64 probes is impossible (PCG has
        // hidden state); instead snapshot the struct fields directly.
        let rng = &self.rng;
        // Safety note: Pcg32 is two u64s; expose via a stable encoding.
        let (state, inc) = pcg_fields(rng);
        w.u64(state);
        w.u64(inc);
        w.u32(self.steps_used);
        w.u32(self.balloons);
        w.u32(self.cats);
        w.u8(self.passed as u8);
        EnvState(w.finish())
    }

    fn restore(&mut self, state: &EnvState) {
        let mut r = Reader::new(&state.0);
        let raw: [u8; CELLS] = r.bytes().try_into().expect("board size");
        self.board = Board::from_raw(raw);
        let s = r.u64();
        let inc = r.u64();
        self.rng = pcg_from_fields(s, inc);
        self.steps_used = r.u32();
        self.balloons = r.u32();
        self.cats = r.u32();
        self.passed = r.u8() != 0;
        debug_assert!(r.exhausted());
        self.refresh_actions();
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed ^ 0x7a70_67_61_6d_65); // "tapgame" salt
        self.board = Board::random(
            &mut self.rng,
            self.level.colors,
            self.level.goal_cats,
            self.level.p_balloon,
        );
        self.steps_used = 0;
        self.balloons = 0;
        self.cats = 0;
        self.passed = false;
        self.refresh_actions();
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.is_terminal(), "step on terminal tap-game state");
        let region = self
            .actions
            .get(action)
            .unwrap_or_else(|| panic!("illegal tap action {action}"))
            .clone();
        let out = self.board.tap(
            &region,
            self.level.colors,
            self.level.p_balloon,
            self.level.prop_threshold,
            &mut self.rng,
        );
        self.steps_used += 1;
        // Goal accounting: only units still needed produce reward.
        let new_balloons =
            (self.balloons + out.balloons_popped).min(self.level.goal_balloons);
        let new_cats = (self.cats + out.cats_collected).min(self.level.goal_cats);
        let units = (new_balloons - self.balloons.min(self.level.goal_balloons)) as f64
            + 3.0 * (new_cats - self.cats.min(self.level.goal_cats)) as f64;
        self.balloons += out.balloons_popped;
        self.cats += out.cats_collected;
        let mut reward = units / self.level.goal_units();
        if self.goals_done() && !self.passed {
            self.passed = true;
            reward += 1.0;
        }
        if self.level.p_boss > 0.0 && self.rng.chance(self.level.p_boss) {
            self.board.boss_throw(self.level.colors, &mut self.rng);
        }
        self.refresh_actions();
        // Dead board (no size-≥2 region): treat as stuck, terminal.
        let done = self.passed || self.steps_used >= self.level.steps || self.actions.is_empty();
        StepResult { reward, done }
    }

    fn legal_actions(&self) -> Vec<usize> {
        (0..self.actions.len()).collect()
    }

    fn num_actions(&self) -> usize {
        MAX_ACTIONS
    }

    fn is_terminal(&self) -> bool {
        self.passed || self.steps_used >= self.level.steps || self.actions.is_empty()
    }

    fn action_heuristic(&self, action: usize) -> f64 {
        let Some(region) = self.actions.get(action) else {
            return 0.0;
        };
        // Bigger regions and goal-item-adjacent regions are better taps;
        // prop-triggering taps get a bonus. Cats weigh 3x balloons, like
        // the reward.
        let size_term = (region.size() as f64 / 12.0).min(0.5);
        let items = self.board.adjacent_balloons(region) as f64
            + 3.0 * self.board.adjacent_cats(region) as f64;
        let item_term = (items / 4.0).min(0.4);
        let prop_term = if region.size() >= self.level.prop_threshold {
            0.1
        } else {
            0.0
        };
        (size_term + item_term + prop_term).min(1.0)
    }

    fn remaining_fraction(&self) -> f64 {
        1.0 - self.steps_used as f64 / self.level.steps as f64
    }

    fn heuristic_value(&self) -> f64 {
        // Progress already banked minus an estimate of the shortfall
        // relative to the remaining budget.
        let progress = self.progress();
        let remaining = self.remaining_fraction();
        (progress + remaining - 1.0).clamp(-1.0, 1.0)
    }

    fn summary_features(&self, out: &mut [f32]) {
        let hist = self.board.color_histogram(self.level.colors);
        for (i, v) in hist.iter().enumerate().take(out.len()) {
            out[i] = *v;
        }
        if out.len() > 8 {
            out[6] = self.board.balloons_on_board() as f32 / CELLS as f32;
            out[7] = self.board.cats_on_board() as f32 / 9.0;
            out[8] = self.actions.len() as f32 / MAX_ACTIONS as f32;
        }
        if out.len() > 10 {
            out[9] = self.progress() as f32;
            out[10] = self.actions.first().map_or(0.0, |r| r.size() as f32 / 20.0);
        }
    }

    fn clone_boxed(&self) -> Box<dyn Env> {
        Box::new(self.clone())
    }

    fn name(&self) -> &str {
        &self.level.id
    }
}

// Pcg32 field access: the rng lives in util and deliberately hides its
// fields; for snapshots we round-trip it through its Debug-stable layout.
// To keep this safe and explicit, util::rng gains no public accessors —
// instead we transmute-free encode via unsafe-free reconstruction below.
fn pcg_fields(rng: &Pcg32) -> (u64, u64) {
    rng.state_and_inc()
}

fn pcg_from_fields(state: u64, inc: u64) -> Pcg32 {
    Pcg32::from_state_and_inc(state, inc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn game() -> TapGame {
        TapGame::new(Level::level35(), 42)
    }

    #[test]
    fn reset_produces_playable_state() {
        let g = game();
        assert!(!g.is_terminal());
        assert!(!g.legal_actions().is_empty());
        assert_eq!(g.num_actions(), MAX_ACTIONS);
        assert_eq!(g.steps_used(), 0);
    }

    #[test]
    fn step_consumes_budget_and_eventually_terminates() {
        let mut g = game();
        let mut total_steps = 0;
        while !g.is_terminal() {
            let acts = g.legal_actions();
            let r = g.step(acts[0]);
            total_steps += 1;
            assert!(r.reward >= 0.0);
            assert!(total_steps <= g.level().steps, "budget respected");
        }
        assert_eq!(g.steps_used(), total_steps);
    }

    #[test]
    fn snapshot_restore_roundtrip_bitexact() {
        let mut g = game();
        g.step(0);
        g.step(0);
        let snap = g.snapshot();
        let mut g2 = TapGame::new(Level::level35(), 7);
        g2.restore(&snap);
        // Replay identical action sequences from both copies.
        let mut a = g.clone();
        let mut b = g2;
        while !a.is_terminal() {
            let act = a.legal_actions()[0];
            let ra = a.step(act);
            let rb = b.step(act);
            assert_eq!(ra, rb);
        }
        assert!(b.is_terminal());
        assert_eq!(a.steps_used(), b.steps_used());
    }

    #[test]
    fn determinism_same_seed_same_trajectory() {
        let run = |seed| {
            let mut g = TapGame::new(Level::level58(), seed);
            let mut rewards = Vec::new();
            while !g.is_terminal() {
                let acts = g.legal_actions();
                rewards.push(g.step(acts[acts.len() / 2]).reward);
            }
            (rewards, g.steps_used(), g.passed())
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn different_seeds_differ() {
        let g1 = TapGame::new(Level::level35(), 1);
        let g2 = TapGame::new(Level::level35(), 2);
        assert_ne!(g1.board.raw(), g2.board.raw());
    }

    #[test]
    fn features_respect_contract() {
        use crate::env::{FEAT_FRAC_INDEX, FEAT_MASK_OFFSET, FEAT_VALUE_INDEX, FEATURE_DIM};
        let g = game();
        let mut f = vec![0f32; FEATURE_DIM];
        g.features(&mut f);
        let legal = g.legal_actions();
        for a in 0..MAX_ACTIONS {
            let is_legal = legal.contains(&a);
            assert_eq!(f[FEAT_MASK_OFFSET + a] > 0.5, is_legal);
            if !is_legal {
                assert_eq!(f[a], 0.0);
            }
        }
        assert!((0.0..=1.0).contains(&f[FEAT_FRAC_INDEX]));
        assert!((-1.0..=1.0).contains(&f[FEAT_VALUE_INDEX]));
    }

    #[test]
    fn reward_bounded_and_pass_bonus_once() {
        // Play many seeds greedily; cumulative reward must stay <= 2.0
        // (1.0 goal units + 1.0 pass bonus).
        for seed in 0..20 {
            let mut g = TapGame::new(Level::level35(), seed);
            let mut total = 0.0;
            while !g.is_terminal() {
                let acts = g.legal_actions();
                // greedy on heuristic
                let best = acts
                    .iter()
                    .copied()
                    .max_by(|&a, &b| {
                        g.action_heuristic(a)
                            .partial_cmp(&g.action_heuristic(b))
                            .unwrap()
                    })
                    .unwrap();
                total += g.step(best).reward;
            }
            assert!(total <= 2.0 + 1e-9, "seed {seed}: total {total}");
            if g.passed() {
                assert!(total >= 1.0, "pass implies all goal units + bonus");
            }
        }
    }

    #[test]
    fn greedy_beats_worst_action_on_average() {
        let play = |pick_best: bool| -> f64 {
            let mut passes = 0;
            for seed in 0..30 {
                let mut g = TapGame::new(Level::level35(), seed);
                while !g.is_terminal() {
                    let acts = g.legal_actions();
                    let chosen = if pick_best {
                        acts.iter()
                            .copied()
                            .max_by(|&a, &b| {
                                g.action_heuristic(a)
                                    .partial_cmp(&g.action_heuristic(b))
                                    .unwrap()
                            })
                            .unwrap()
                    } else {
                        *acts.last().unwrap() // smallest region
                    };
                    g.step(chosen);
                }
                passes += g.passed() as u32;
            }
            passes as f64 / 30.0
        };
        let greedy = play(true);
        let worst = play(false);
        assert!(
            greedy >= worst,
            "greedy pass-rate {greedy} should be >= worst-action {worst}"
        );
    }

    #[test]
    fn progress_monotone_nondecreasing() {
        let mut g = TapGame::new(Level::level58(), 11);
        let mut last = g.progress();
        while !g.is_terminal() {
            g.step(g.legal_actions()[0]);
            let p = g.progress();
            assert!(p >= last - 1e-12);
            last = p;
        }
    }
}
