//! Level definitions for the tap-elimination game.
//!
//! A [`Level`] bundles the rule parameters Appendix C.1 describes: board
//! colors, step budget, goal items, prop threshold and the "boss level"
//! randomness. Named constructors provide the two levels the paper analyses
//! (Level-35-like: easy; Level-58-like: hard), and [`LevelGen`] produces the
//! 300-train / 130-eval level sets for the pass-rate prediction system.

use crate::util::rng::Pcg32;

/// Parameter set for one game level.
#[derive(Debug, Clone, PartialEq)]
pub struct Level {
    /// Identifier shown in tables ("level-35", "gen-0042", ...).
    pub id: String,
    /// Number of distinct cell colors (more colors => smaller regions =>
    /// harder; real levels range ~3-6).
    pub colors: u8,
    /// Tap budget: the level fails when it is exhausted.
    pub steps: u32,
    /// Balloons to collect (pop by eliminating an adjacent region).
    pub goal_balloons: u32,
    /// Cats to collect (fall with gravity; collected at the bottom row).
    pub goal_cats: u32,
    /// Probability that a refilled cell is a balloon.
    pub p_balloon: f64,
    /// Region size that awards a row-clearing rocket prop.
    pub prop_threshold: usize,
    /// Per-step probability the "boss" recolors a random cell
    /// (Appendix C.1's boss-level randomness; 0 for normal levels).
    pub p_boss: f64,
}

impl Level {
    /// Level-35 analogue: "relatively simple, requiring 18 steps for an
    /// average player to pass" (Section 5.1, footnote 6).
    pub fn level35() -> Level {
        Level {
            id: "level-35".into(),
            colors: 4,
            steps: 18,
            goal_balloons: 16,
            goal_cats: 0,
            p_balloon: 0.14,
            prop_threshold: 6,
            p_boss: 0.0,
        }
    }

    /// Level-58 analogue: "relatively difficult and needs more than 50
    /// steps to solve". Goals calibrated so a strong search agent passes
    /// in roughly 30–50 taps while weak players usually fail.
    pub fn level58() -> Level {
        Level {
            id: "level-58".into(),
            colors: 5,
            steps: 55,
            goal_balloons: 30,
            goal_cats: 3,
            p_balloon: 0.12,
            prop_threshold: 7,
            p_boss: 0.05,
        }
    }

    /// Total goal units (balloons weigh 1, cats weigh 3) — the reward
    /// normalizer used by the environment.
    pub fn goal_units(&self) -> f64 {
        self.goal_balloons as f64 + 3.0 * self.goal_cats as f64
    }
}

/// Seeded generator of parameterized levels across a difficulty spread,
/// used for the pass-rate system's 300-train / 130-eval sets.
#[derive(Debug)]
pub struct LevelGen {
    rng: Pcg32,
    counter: u32,
}

impl LevelGen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed), counter: 0 }
    }

    /// Draw the next level. `difficulty` in [0, 1] biases every knob from
    /// easy (0) to hard (1); the generator adds per-level jitter so levels
    /// at equal difficulty still differ.
    pub fn generate(&mut self, difficulty: f64) -> Level {
        assert!((0.0..=1.0).contains(&difficulty));
        let d = difficulty;
        let jitter = |rng: &mut Pcg32, spread: f64| rng.range_f64(-spread, spread);
        let colors = (3.0 + 3.0 * d + jitter(&mut self.rng, 0.8)).round().clamp(3.0, 6.0) as u8;
        let steps = (14.0 + 40.0 * d + jitter(&mut self.rng, 4.0)).round().clamp(8.0, 60.0) as u32;
        let goal_balloons =
            (10.0 + 30.0 * d + jitter(&mut self.rng, 5.0)).round().clamp(5.0, 48.0) as u32;
        let goal_cats = if self.rng.chance(0.25 + 0.35 * d) {
            1 + self.rng.below(3)
        } else {
            0
        };
        let p_balloon = (0.16 - 0.07 * d + jitter(&mut self.rng, 0.02)).clamp(0.05, 0.2);
        let prop_threshold = if d > 0.6 { 7 } else { 6 };
        let p_boss = if self.rng.chance(0.15) { 0.05 } else { 0.0 };
        let id = format!("gen-{:04}", self.counter);
        self.counter += 1;
        Level {
            id,
            colors,
            steps,
            goal_balloons,
            goal_cats,
            p_balloon,
            prop_threshold,
            p_boss,
        }
    }

    /// Generate `n` levels with difficulties evenly spread over [0, 1].
    pub fn batch(&mut self, n: usize) -> Vec<Level> {
        (0..n)
            .map(|i| {
                let d = if n <= 1 { 0.5 } else { i as f64 / (n - 1) as f64 };
                self.generate(d)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_levels_match_paper_shape() {
        let l35 = Level::level35();
        let l58 = Level::level58();
        assert_eq!(l35.steps, 18); // "18 steps for an average player"
        assert!(l58.steps > 50); // "more than 50 steps"
        assert!(l58.colors > l35.colors);
        assert!(l58.goal_units() > l35.goal_units());
    }

    #[test]
    fn goal_units_weighting() {
        let mut l = Level::level35();
        l.goal_balloons = 10;
        l.goal_cats = 2;
        assert_eq!(l.goal_units(), 16.0);
    }

    #[test]
    fn generator_is_deterministic() {
        let a: Vec<Level> = LevelGen::new(9).batch(20);
        let b: Vec<Level> = LevelGen::new(9).batch(20);
        assert_eq!(a, b);
    }

    #[test]
    fn difficulty_monotone_in_expectation() {
        let mut g = LevelGen::new(1);
        let easy: Vec<Level> = (0..50).map(|_| g.generate(0.0)).collect();
        let hard: Vec<Level> = (0..50).map(|_| g.generate(1.0)).collect();
        let avg = |ls: &[Level], f: &dyn Fn(&Level) -> f64| {
            ls.iter().map(f).sum::<f64>() / ls.len() as f64
        };
        assert!(avg(&hard, &|l| l.colors as f64) > avg(&easy, &|l| l.colors as f64));
        assert!(avg(&hard, &|l| l.steps as f64) > avg(&easy, &|l| l.steps as f64));
        assert!(avg(&hard, &|l| l.goal_balloons as f64) > avg(&easy, &|l| l.goal_balloons as f64));
    }

    #[test]
    fn batch_ids_unique() {
        let mut g = LevelGen::new(2);
        let ls = g.batch(30);
        let mut ids: Vec<&str> = ls.iter().map(|l| l.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 30);
    }

    #[test]
    fn parameters_stay_in_bounds() {
        let mut g = LevelGen::new(3);
        for i in 0..200 {
            let l = g.generate((i % 101) as f64 / 100.0);
            assert!((3..=6).contains(&l.colors));
            assert!((8..=60).contains(&l.steps));
            assert!((5..=48).contains(&l.goal_balloons));
            assert!(l.goal_cats <= 3);
            assert!((0.05..=0.2).contains(&l.p_balloon));
        }
    }
}
