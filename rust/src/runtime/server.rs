//! Dynamic-batching inference server.
//!
//! The PJRT executable is owned by one server thread; simulation workers
//! talk to it through cloneable [`EvalHandle`]s. The server greedily
//! coalesces concurrent requests into one padded batch (up to the largest
//! exported batch size, with a short gather window), which is what makes
//! a 16-worker WU-UCT run amortize the network cost — the same dynamic
//! batching trick serving systems (vLLM-style routers) use.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::runtime::engine::{Engine, PolicyOutput};

/// A single inference request: features in, (logits, value) out.
struct EvalRequest {
    features: Vec<f32>,
    reply: Sender<PolicyOutput>,
}

/// Server statistics (for the batching-efficiency bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
}

impl ServerStats {
    /// Mean rows per PJRT execution — the batching win.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Clonable client handle to the inference server.
#[derive(Clone)]
pub struct EvalHandle {
    tx: Sender<EvalRequest>,
}

impl EvalHandle {
    /// Blocking evaluation of one feature vector.
    pub fn eval(&self, features: Vec<f32>) -> PolicyOutput {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(EvalRequest { features, reply: reply_tx })
            .expect("eval server hung up");
        reply_rx.recv().expect("eval server dropped the reply")
    }
}

/// The server: owns the engine thread.
pub struct EvalServer {
    tx: Option<Sender<EvalRequest>>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
}

impl EvalServer {
    /// Start the server on the artifacts in `dir`.
    ///
    /// `gather_window`: how long to wait for additional requests after the
    /// first one before running the batch (the batching/latency knob the
    /// `micro_hotpath` bench sweeps).
    pub fn start(dir: &Path, gather_window: Duration) -> Result<EvalServer> {
        let (tx, rx): (Sender<EvalRequest>, Receiver<EvalRequest>) = channel();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_thread = Arc::clone(&stats);
        // PJRT handles are not Send: the engine must be constructed on the
        // server thread itself; startup errors come back over a channel.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let dir = dir.to_path_buf();
        let handle = std::thread::spawn(move || {
            let mut engine = match Engine::load(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let max_batch = *engine.meta().policy_batches.last().unwrap();
            loop {
                // Block for the first request of the batch.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return, // all handles dropped: shut down
                };
                let mut pending = vec![first];
                // Gather more within the window, up to the batch cap.
                while pending.len() < max_batch {
                    match rx.recv_timeout(gather_window) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let rows: Vec<Vec<f32>> =
                    pending.iter().map(|r| r.features.clone()).collect();
                match engine.infer(&rows) {
                    Ok(outputs) => {
                        {
                            let mut s = stats_thread.lock().unwrap();
                            s.requests += pending.len() as u64;
                            s.batches += 1;
                        }
                        for (req, out) in pending.into_iter().zip(outputs) {
                            let _ = req.reply.send(out);
                        }
                    }
                    Err(e) => {
                        // Drop the replies; clients will panic with a clear
                        // message. An inference error is unrecoverable.
                        eprintln!("eval server: inference failed: {e:#}");
                        return;
                    }
                }
            }
        });
        ready_rx
            .recv()
            .expect("eval server thread died before reporting readiness")?;
        Ok(EvalServer { tx: Some(tx), handle: Some(handle), stats })
    }

    pub fn handle(&self) -> EvalHandle {
        EvalHandle { tx: self.tx.as_ref().expect("server running").clone() }
    }

    pub fn stats(&self) -> ServerStats {
        *self.stats.lock().unwrap()
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        // Close the channel; the thread exits once in-flight work drains.
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{FEATURE_DIM};
    use crate::runtime::meta::artifacts_dir;

    fn server() -> Option<EvalServer> {
        let dir = artifacts_dir();
        if !dir.join("meta.txt").exists() {
            eprintln!("artifacts missing — run `make artifacts` (test skipped)");
            return None;
        }
        Some(EvalServer::start(&dir, Duration::from_micros(200)).unwrap())
    }

    fn features(seed: u64) -> Vec<f32> {
        let env = crate::env::atari::make("Alien", seed);
        let mut f = vec![0f32; FEATURE_DIM];
        env.features(&mut f);
        f
    }

    #[test]
    fn single_request_round_trip() {
        let Some(s) = server() else { return };
        let out = s.handle().eval(features(1));
        assert_eq!(out.logits.len(), crate::env::MAX_ACTIONS);
        assert!(out.value.is_finite());
        assert_eq!(s.stats().requests, 1);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let Some(s) = server() else { return };
        let n = 24;
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let h = s.handle();
                std::thread::spawn(move || h.eval(features(i as u64)))
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert!(out.value.is_finite());
        }
        let stats = s.stats();
        assert_eq!(stats.requests, n as u64);
        assert!(
            stats.batches < n as u64,
            "no batching happened: {stats:?}"
        );
        assert!(stats.avg_batch() > 1.0);
    }

    #[test]
    fn server_results_match_engine_directly() {
        let Some(s) = server() else { return };
        let f = features(9);
        let via_server = s.handle().eval(f.clone());
        let mut engine = Engine::load(&artifacts_dir()).unwrap();
        let direct = engine.infer(&[f]).unwrap().remove(0);
        for (a, b) in via_server.logits.iter().zip(&direct.logits) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!((via_server.value - direct.value).abs() < 1e-5);
    }

    #[test]
    fn shutdown_is_clean() {
        let Some(s) = server() else { return };
        let h = s.handle();
        let _ = h.eval(features(0));
        drop(h);
        drop(s); // must join without hanging
    }
}
