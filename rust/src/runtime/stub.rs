//! API-compatible stand-ins for the PJRT runtime, compiled when the
//! `pjrt` cargo feature is off (the `xla` bindings crate is not in the
//! offline registry snapshot — see DESIGN.md §4).
//!
//! Every constructor fails with a clear error instead of executing, so the
//! callers that gate on `artifacts/meta.txt` at runtime keep compiling and
//! keep skipping cleanly when artifacts are absent. If artifacts *are*
//! present but the feature is off, loading reports the misconfiguration
//! instead of silently returning garbage.

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::eval::PolicyFactory;
use crate::runtime::meta::ArtifactMeta;

const MISSING: &str =
    "built without the `pjrt` feature: rebuild with `--features pjrt` (requires the xla crate)";

/// One (logits, value) pair per request row.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyOutput {
    pub logits: Vec<f32>,
    pub value: f32,
}

/// Stub engine: construction always fails.
pub struct Engine {
    meta: ArtifactMeta,
    pub batches_run: u64,
    pub rows_run: u64,
}

impl Engine {
    pub fn load(_dir: &Path) -> Result<Engine> {
        bail!(MISSING);
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn infer(&mut self, _rows: &[Vec<f32>]) -> Result<Vec<PolicyOutput>> {
        bail!(MISSING);
    }
}

/// Server statistics (kept for API parity with the real server).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
}

impl ServerStats {
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Stub client handle; unobtainable because the server never starts.
#[derive(Clone)]
pub struct EvalHandle;

impl EvalHandle {
    pub fn eval(&self, _features: Vec<f32>) -> PolicyOutput {
        unreachable!("stub EvalServer cannot be started")
    }
}

/// Stub inference server: start always fails.
pub struct EvalServer;

impl EvalServer {
    pub fn start(_dir: &Path, _gather_window: Duration) -> Result<EvalServer> {
        bail!(MISSING);
    }

    pub fn handle(&self) -> EvalHandle {
        EvalHandle
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats::default()
    }
}

/// Stub network policy: the factory panics if a worker ever invokes it.
pub struct NetworkPolicy;

impl NetworkPolicy {
    pub fn factory(handle: EvalHandle) -> PolicyFactory {
        let _ = handle;
        std::sync::Arc::new(|_seed| unreachable!("stub NetworkPolicy cannot roll out"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn engine_load_reports_missing_feature() {
        let err = Engine::load(&artifacts_dir()).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn server_start_reports_missing_feature() {
        let err = EvalServer::start(&artifacts_dir(), Duration::from_micros(1)).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn stats_default_is_empty() {
        let s = ServerStats::default();
        assert_eq!(s.requests, 0);
        assert_eq!(s.avg_batch(), 0.0);
    }
}
