//! PJRT execution engine: load the AOT-compiled HLO-text artifacts and run
//! the policy-value network from Rust.
//!
//! This is the runtime half of the three-layer architecture: python/jax
//! lowered the fused-Pallas forward pass to HLO **text** once (`make
//! artifacts`); here we parse it (`HloModuleProto::from_text_file` — the
//! id-safe interchange, see DESIGN.md), compile it on the PJRT CPU client
//! and execute it with concrete feature batches. One executable is
//! compiled per exported batch size; requests are padded to the smallest
//! fitting batch and chunked above the largest.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::meta::ArtifactMeta;

/// One (logits, value) pair per request row.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyOutput {
    pub logits: Vec<f32>,
    pub value: f32,
}

/// The PJRT engine: compiled executables keyed by batch size.
pub struct Engine {
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
    /// Cumulative executed batches / rows (perf accounting).
    pub batches_run: u64,
    pub rows_run: u64,
}

impl Engine {
    /// Load every `policy_value_b{B}.hlo.txt` listed in `meta.txt`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let meta = ArtifactMeta::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for &b in &meta.policy_batches {
            let path = dir.join(format!("policy_value_b{b}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            exes.insert(b, exe);
        }
        Ok(Engine { client, exes, meta, batches_run: 0, rows_run: 0 })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run the network on `rows.len()` feature vectors (each of length
    /// `feature_dim`). Pads to the smallest exported batch; chunks when
    /// the request exceeds the largest.
    pub fn infer(&mut self, rows: &[Vec<f32>]) -> Result<Vec<PolicyOutput>> {
        let f = self.meta.feature_dim;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(r.len() == f, "row {i}: {} features, want {f}", r.len());
        }
        let max_b = *self.meta.policy_batches.last().unwrap();
        let mut out = Vec::with_capacity(rows.len());
        let mut start = 0;
        while start < rows.len() {
            let n = (rows.len() - start).min(max_b);
            let chunk = &rows[start..start + n];
            out.extend(self.infer_chunk(chunk)?);
            start += n;
        }
        Ok(out)
    }

    fn infer_chunk(&mut self, rows: &[Vec<f32>]) -> Result<Vec<PolicyOutput>> {
        let n = rows.len();
        let b = self.meta.batch_for(n);
        let f = self.meta.feature_dim;
        let mut flat = vec![0f32; b * f];
        for (i, row) in rows.iter().enumerate() {
            flat[i * f..(i + 1) * f].copy_from_slice(row);
        }
        let input = xla::Literal::vec1(&flat)
            .reshape(&[b as i64, f as i64])
            .context("reshaping input literal")?;
        let exe = self.exes.get(&b).expect("batch_for returned unexported size");
        let result = exe
            .execute::<xla::Literal>(&[input])
            .context("executing policy network")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<f32>().context("reading result values")?;
        anyhow::ensure!(
            values.len() == b * self.meta.out_dim,
            "unexpected output length {} for batch {b}",
            values.len()
        );
        self.batches_run += 1;
        self.rows_run += n as u64;
        let o = self.meta.out_dim;
        let a = self.meta.num_actions;
        let vi = self.meta.value_index;
        Ok((0..n)
            .map(|i| PolicyOutput {
                logits: values[i * o..i * o + a].to_vec(),
                value: values[i * o + vi],
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{FEATURE_DIM, MAX_ACTIONS};
    use crate::runtime::meta::artifacts_dir;

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir();
        if !dir.join("meta.txt").exists() {
            eprintln!("artifacts missing — run `make artifacts` (test skipped)");
            return None;
        }
        Some(Engine::load(&dir).expect("engine load"))
    }

    fn env_features(seed: u64) -> Vec<f32> {
        let env = crate::env::atari::make("Breakout", seed);
        let mut f = vec![0f32; FEATURE_DIM];
        env.features(&mut f);
        f
    }

    #[test]
    fn loads_and_runs_single_row() {
        let Some(mut e) = engine() else { return };
        let out = e.infer(&[env_features(1)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].logits.len(), MAX_ACTIONS);
        assert!(out[0].value.is_finite());
        assert!(out[0].logits.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn batch_sizes_pad_and_chunk() {
        let Some(mut e) = engine() else { return };
        for n in [1usize, 2, 7, 8, 9, 33, 70] {
            let rows: Vec<Vec<f32>> = (0..n).map(|i| env_features(i as u64)).collect();
            let out = e.infer(&rows).unwrap();
            assert_eq!(out.len(), n, "n={n}");
        }
    }

    #[test]
    fn padding_does_not_change_results() {
        let Some(mut e) = engine() else { return };
        let row = env_features(5);
        let single = e.infer(&[row.clone()]).unwrap();
        let padded = e.infer(&[row.clone(), env_features(6)]).unwrap();
        for (a, b) in single[0].logits.iter().zip(&padded[0].logits) {
            assert!((a - b).abs() < 1e-4, "padding changed logits: {a} vs {b}");
        }
        assert!((single[0].value - padded[0].value).abs() < 1e-4);
    }

    #[test]
    fn network_matches_teacher_ranking() {
        // The distilled net should rank actions like the heuristic teacher
        // on real env features — the end-to-end L1/L2/runtime contract.
        let Some(mut e) = engine() else { return };
        let mut agree = 0;
        let total: u32 = 20;
        for seed in 0..total as u64 {
            let env = crate::env::atari::make("Breakout", seed);
            let mut f = vec![0f32; FEATURE_DIM];
            env.features(&mut f);
            let out = &e.infer(&[f.clone()]).unwrap()[0];
            let legal = env.legal_actions();
            let net_best = legal
                .iter()
                .copied()
                .max_by(|&a, &b| out.logits[a].partial_cmp(&out.logits[b]).unwrap())
                .unwrap();
            let teacher_best = legal
                .iter()
                .copied()
                .max_by(|&a, &b| {
                    env.action_heuristic(a)
                        .partial_cmp(&env.action_heuristic(b))
                        .unwrap()
                })
                .unwrap();
            agree += (net_best == teacher_best) as u32;
        }
        assert!(agree * 2 > total, "net/teacher agreement {agree}/{total}");
    }

    #[test]
    fn wrong_feature_dim_rejected() {
        let Some(mut e) = engine() else { return };
        assert!(e.infer(&[vec![0f32; 7]]).is_err());
    }

    #[test]
    fn row_counters_accumulate() {
        let Some(mut e) = engine() else { return };
        e.infer(&[env_features(0)]).unwrap();
        e.infer(&[env_features(1), env_features(2)]).unwrap();
        assert_eq!(e.rows_run, 3);
        assert!(e.batches_run >= 2);
    }
}
