//! Artifact metadata: the contract file `artifacts/meta.txt` written by
//! `python/compile/aot.py`, describing the exported HLO modules' shapes.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Parsed `meta.txt`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub feature_dim: usize,
    pub num_actions: usize,
    pub out_dim: usize,
    pub value_index: usize,
    /// Exported forward-pass batch sizes, ascending.
    pub policy_batches: Vec<usize>,
    pub select_batch: usize,
    pub teacher_scale: f64,
    pub illegal_logit: f64,
    pub distill_final_loss: f64,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let get = |key: &str| -> Result<&str> {
            text.lines()
                .filter_map(|l| l.split_once('='))
                .find(|(k, _)| k.trim() == key)
                .map(|(_, v)| v.trim())
                .ok_or_else(|| anyhow!("meta.txt missing key {key}"))
        };
        let num = |key: &str| -> Result<usize> {
            get(key)?.parse().with_context(|| format!("meta.txt: bad {key}"))
        };
        let fnum = |key: &str| -> Result<f64> {
            get(key)?.parse().with_context(|| format!("meta.txt: bad {key}"))
        };
        let mut policy_batches: Vec<usize> = get("policy_batches")?
            .split(',')
            .map(|t| t.trim().parse().context("bad batch size"))
            .collect::<Result<_>>()?;
        policy_batches.sort_unstable();
        let meta = ArtifactMeta {
            feature_dim: num("feature_dim")?,
            num_actions: num("num_actions")?,
            out_dim: num("out_dim")?,
            value_index: num("value_index")?,
            policy_batches,
            select_batch: num("select_batch")?,
            teacher_scale: fnum("teacher_scale")?,
            illegal_logit: fnum("illegal_logit")?,
            distill_final_loss: fnum("distill_final_loss")?,
        };
        // Must agree with the Rust-side feature contract.
        anyhow::ensure!(
            meta.feature_dim == crate::env::FEATURE_DIM,
            "feature_dim mismatch: artifacts {} vs crate {}",
            meta.feature_dim,
            crate::env::FEATURE_DIM
        );
        anyhow::ensure!(
            meta.num_actions == crate::env::MAX_ACTIONS,
            "num_actions mismatch: artifacts {} vs crate {}",
            meta.num_actions,
            crate::env::MAX_ACTIONS
        );
        Ok(meta)
    }

    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Smallest exported batch that fits `n` rows (largest if none fits —
    /// the engine then chunks).
    pub fn batch_for(&self, n: usize) -> usize {
        self.policy_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *self.policy_batches.last().expect("no batches"))
    }
}

/// Locate the artifacts directory: `$WU_UCT_ARTIFACTS`, else
/// `<manifest>/artifacts`, else `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("WU_UCT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "feature_dim=128\nnum_actions=16\nout_dim=32\nvalue_index=16\n\
                          policy_batches=32,1,8\nselect_batch=64\nteacher_scale=4.0\n\
                          illegal_logit=-8.0\ndistill_final_loss=0.25\n";

    #[test]
    fn parses_and_sorts_batches() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.feature_dim, 128);
        assert_eq!(m.policy_batches, vec![1, 8, 32]);
        assert_eq!(m.value_index, 16);
        assert!((m.teacher_scale - 4.0).abs() < 1e-12);
    }

    #[test]
    fn batch_for_picks_smallest_fitting() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.batch_for(1), 1);
        assert_eq!(m.batch_for(2), 8);
        assert_eq!(m.batch_for(8), 8);
        assert_eq!(m.batch_for(9), 32);
        assert_eq!(m.batch_for(100), 32); // chunked by the engine
    }

    #[test]
    fn missing_key_errors() {
        assert!(ArtifactMeta::parse("feature_dim=128").is_err());
    }

    #[test]
    fn contract_mismatch_errors() {
        let bad = SAMPLE.replace("feature_dim=128", "feature_dim=64");
        let err = ArtifactMeta::parse(&bad).unwrap_err();
        assert!(err.to_string().contains("mismatch"));
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let dir = artifacts_dir();
        if dir.join("meta.txt").exists() {
            let m = ArtifactMeta::load(&dir).unwrap();
            assert_eq!(m.feature_dim, crate::env::FEATURE_DIM);
        }
    }
}
