//! The network rollout policy: the distilled policy-value net served by
//! the PJRT inference server, used as the simulation default policy —
//! the role the distilled PPO network plays in the paper (Appendix D).

use std::sync::Arc;

use crate::env::{Env, FEATURE_DIM};
use crate::eval::{PolicyFactory, RolloutPolicy};
use crate::runtime::server::EvalHandle;
use crate::util::rng::Pcg32;

/// Rollout policy backed by the AOT-compiled network.
pub struct NetworkPolicy {
    handle: EvalHandle,
    rng: Pcg32,
    features: Vec<f32>,
}

impl NetworkPolicy {
    pub fn new(handle: EvalHandle, seed: u64) -> Self {
        Self {
            handle,
            rng: Pcg32::new(seed ^ 0x4e7),
            features: vec![0f32; FEATURE_DIM],
        }
    }

    /// Factory for worker pools: each worker gets its own rng stream but
    /// shares the inference server through the cloned handle.
    pub fn factory(handle: EvalHandle) -> PolicyFactory {
        Arc::new(move |seed| Box::new(NetworkPolicy::new(handle.clone(), seed)))
    }

    fn eval_env(&mut self, env: &dyn Env) -> crate::runtime::engine::PolicyOutput {
        env.features(&mut self.features);
        self.handle.eval(self.features.clone())
    }
}

impl RolloutPolicy for NetworkPolicy {
    fn choose(&mut self, env: &dyn Env) -> usize {
        let legal = env.legal_actions();
        assert!(!legal.is_empty(), "choose() with no legal actions");
        let out = self.eval_env(env);
        // Softmax sample over legal logits (stable exp).
        let logits: Vec<f64> = legal.iter().map(|&a| out.logits[a] as f64).collect();
        let max = logits.iter().cloned().fold(f64::MIN, f64::max);
        let weights: Vec<f64> = logits.iter().map(|l| (l - max).exp()).collect();
        legal[self.rng.weighted(&weights)]
    }

    fn value(&mut self, env: &dyn Env) -> f64 {
        self.eval_env(env).value as f64
    }

    fn name(&self) -> &'static str {
        "network"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::meta::artifacts_dir;
    use crate::runtime::server::EvalServer;
    use std::time::Duration;

    fn server() -> Option<EvalServer> {
        let dir = artifacts_dir();
        if !dir.join("meta.txt").exists() {
            eprintln!("artifacts missing — run `make artifacts` (test skipped)");
            return None;
        }
        Some(EvalServer::start(&dir, Duration::from_micros(100)).unwrap())
    }

    #[test]
    fn network_policy_picks_legal_actions() {
        let Some(s) = server() else { return };
        let env = crate::env::atari::make("SpaceInvaders", 3);
        let mut p = NetworkPolicy::new(s.handle(), 1);
        for _ in 0..10 {
            let a = p.choose(env.as_ref());
            assert!(env.legal_actions().contains(&a));
        }
        assert!(p.value(env.as_ref()).is_finite());
    }

    #[test]
    fn factory_clones_share_server() {
        let Some(s) = server() else { return };
        let f = NetworkPolicy::factory(s.handle());
        let env = crate::env::atari::make("Alien", 5);
        let mut p1 = f(1);
        let mut p2 = f(2);
        let _ = p1.choose(env.as_ref());
        let _ = p2.choose(env.as_ref());
        assert!(s.stats().requests >= 2);
    }

    #[test]
    fn network_mode_tracks_heuristic_mode() {
        // Distillation quality end-to-end: the network's modal action
        // should usually agree with the teacher's argmax.
        let Some(s) = server() else { return };
        let mut agree = 0;
        let total: u32 = 15;
        for seed in 0..total as u64 {
            let env = crate::env::atari::make("RoadRunner", seed);
            let mut p = NetworkPolicy::new(s.handle(), seed);
            let mut counts = std::collections::HashMap::new();
            for _ in 0..30 {
                *counts.entry(p.choose(env.as_ref())).or_insert(0) += 1;
            }
            let modal = *counts.iter().max_by_key(|(_, &c)| c).unwrap().0;
            let legal = env.legal_actions();
            let teacher = legal
                .iter()
                .copied()
                .max_by(|&a, &b| {
                    env.action_heuristic(a)
                        .partial_cmp(&env.action_heuristic(b))
                        .unwrap()
                })
                .unwrap();
            agree += (modal == teacher) as u32;
        }
        assert!(agree * 2 >= total, "agreement {agree}/{total}");
    }
}
