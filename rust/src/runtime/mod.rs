//! Runtime layer: PJRT loading/execution of the AOT artifacts, the
//! dynamic-batching inference server, and the network rollout policy.
//!
//! Flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! (`artifacts/*.hlo.txt`) → `client.compile` → `execute`. Python never
//! runs here — the weights were constant-folded at `make artifacts` time.

pub mod engine;
pub mod meta;
pub mod policy;
pub mod server;

pub use engine::{Engine, PolicyOutput};
pub use meta::{artifacts_dir, ArtifactMeta};
pub use policy::NetworkPolicy;
pub use server::{EvalHandle, EvalServer, ServerStats};
