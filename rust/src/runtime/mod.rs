//! Runtime layer: PJRT loading/execution of the AOT artifacts, the
//! dynamic-batching inference server, and the network rollout policy.
//!
//! Flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! (`artifacts/*.hlo.txt`) → `client.compile` → `execute`. Python never
//! runs here — the weights were constant-folded at `make artifacts` time.
//!
//! The PJRT half needs the `xla` bindings crate, which is not in the
//! offline registry snapshot; it is gated behind the `pjrt` cargo feature
//! (see DESIGN.md §4). Without the feature, [`stub`] provides API-identical
//! types whose constructors report the missing feature, so everything that
//! checks for artifacts at runtime still compiles and degrades gracefully.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod meta;
#[cfg(feature = "pjrt")]
pub mod policy;
#[cfg(feature = "pjrt")]
pub mod server;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, PolicyOutput};
pub use meta::{artifacts_dir, ArtifactMeta};
#[cfg(feature = "pjrt")]
pub use policy::NetworkPolicy;
#[cfg(feature = "pjrt")]
pub use server::{EvalHandle, EvalServer, ServerStats};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, EvalHandle, EvalServer, NetworkPolicy, PolicyOutput, ServerStats};
