//! Fig. 10 (Appendix D): relative performance of WU-UCT over each
//! baseline, per game, plus the average percentile improvement.
//!
//! Derived from Table-1 data: for each game and baseline B,
//! `rel = (mean(WU-UCT) − mean(B)) / |mean(B)|` (the paper excludes games
//! where the baseline's mean is 0 from the average, as footnote 10 does
//! for Tennis/RootP).

use crate::experiments::table1::{Table1Data, ALGOS};
use crate::util::stats::mean;
use crate::util::table::Table;

/// Relative improvements: per game, vs TreeP / LeafP / RootP.
pub fn relative_performance(data: &Table1Data) -> (Table, Vec<f64>) {
    assert_eq!(ALGOS[0], "WU-UCT");
    let baselines = [(1usize, "TreeP"), (2, "LeafP"), (3, "RootP")];
    let mut table = Table::new(
        "Fig 10 — relative performance of WU-UCT vs baselines",
        &["Environment", "vs TreeP", "vs LeafP", "vs RootP"],
    );
    let mut sums = vec![0.0f64; baselines.len()];
    let mut counts = vec![0usize; baselines.len()];
    for (g, game) in data.games.iter().enumerate() {
        let wu = mean(&data.rewards[g][0]);
        let mut cells = vec![game.clone()];
        for (bi, &(ai, _)) in baselines.iter().enumerate() {
            let b = mean(&data.rewards[g][ai]);
            if b.abs() < 1e-9 {
                cells.push("n/a".into()); // footnote-10 exclusion
                continue;
            }
            let rel = (wu - b) / b.abs();
            sums[bi] += rel;
            counts[bi] += 1;
            cells.push(format!("{:+.0}%", rel * 100.0));
        }
        table.row(&cells);
    }
    let avgs: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();
    table.row(&[
        "average".into(),
        format!("{:+.0}%", avgs[0] * 100.0),
        format!("{:+.0}%", avgs[1] * 100.0),
        format!("{:+.0}%", avgs[2] * 100.0),
    ]);
    (table, avgs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_data() -> Table1Data {
        // WU-UCT = 200, TreeP = 100, LeafP = 50, RootP = 0 (excluded),
        // Policy = 10, UCT = 250.
        Table1Data {
            games: vec!["G1".into()],
            rewards: vec![vec![
                vec![200.0, 200.0],
                vec![100.0, 100.0],
                vec![50.0, 50.0],
                vec![0.0, 0.0],
                vec![10.0, 10.0],
                vec![250.0, 250.0],
            ]],
        }
    }

    #[test]
    fn relative_improvements_computed() {
        let (table, avgs) = relative_performance(&fake_data());
        assert_eq!(table.num_rows(), 2); // one game + average row
        assert!((avgs[0] - 1.0).abs() < 1e-9); // +100% vs TreeP
        assert!((avgs[1] - 3.0).abs() < 1e-9); // +300% vs LeafP
        assert_eq!(avgs[2], 0.0); // RootP excluded (zero mean)
    }

    #[test]
    fn negative_improvement_renders() {
        let mut d = fake_data();
        d.rewards[0][1] = vec![400.0, 400.0]; // TreeP beats WU-UCT
        let (_, avgs) = relative_performance(&d);
        assert!(avgs[0] < 0.0);
    }
}
