//! Fig. 4: WU-UCT speedup curves (a–b) and performance retention (c–d)
//! on the two tap-game levels.
//!
//! (a–b): wall-clock speedup as simulation workers scale 1→16 (several
//! expansion-worker settings), on the latency-simulated emulator.
//! (c–d): average game steps to pass the level as workers scale — the
//! paper's "negligible performance loss" claim (σ = 0.67 / 1.22 steps).

use crate::env::tapgame::{Level, TapGame};
use crate::env::Env;
use crate::experiments::table3::{search_time, WORKER_AXIS};
use crate::experiments::Scale;
use crate::mcts::{Search, WuUct};
use crate::util::stats::{mean, std_dev};
use crate::util::table::Table;

/// Speedup curves: rows = expansion workers, cols = simulation workers.
pub fn speedup_curves(level: &Level, exp_axis: &[usize], scale: &Scale, repeats: usize) -> Table {
    let mut table = Table::new(
        format!("Fig 4(a-b) — speedup vs workers, {}", level.id),
        &["Me", "Ms=1", "Ms=2", "Ms=4", "Ms=8", "Ms=16"],
    );
    let base = search_time(level, 1, 1, scale, repeats).as_secs_f64();
    for &me in exp_axis {
        let mut cells = vec![me.to_string()];
        for &ms in &WORKER_AXIS {
            let t = search_time(level, me, ms, scale, repeats).as_secs_f64();
            cells.push(format!("{:.1}", base / t));
        }
        table.row(&cells);
    }
    table
}

/// Game-step statistics for one (level, workers) cell: the performance-
/// retention axis. Returns (mean steps, std, pass rate).
pub fn game_steps(level: &Level, n_exp: usize, n_sim: usize, scale: &Scale) -> (f64, f64, f64) {
    let mut search = WuUct::new(scale.tap_spec(scale.seed ^ 0xf4), n_exp, n_sim);
    let mut steps = Vec::with_capacity(scale.trials);
    let mut passes = 0usize;
    for t in 0..scale.trials {
        let mut game = TapGame::new(level.clone(), scale.seed.wrapping_add(t as u64 * 101));
        while !game.is_terminal() {
            let r = search.search(&game);
            let legal = game.legal_actions();
            let a = if legal.contains(&r.best_action) { r.best_action } else { legal[0] };
            game.step(a);
        }
        steps.push(game.steps_used() as f64);
        passes += game.passed() as usize;
    }
    (mean(&steps), std_dev(&steps), passes as f64 / scale.trials as f64)
}

/// Fig. 4(c–d): game steps vs worker count for both levels.
pub fn performance_retention(scale: &Scale) -> Table {
    let mut table = Table::new(
        "Fig 4(c-d) — game steps (performance) vs workers",
        &["Level", "workers", "mean steps", "std", "pass rate"],
    );
    for level in [Level::level35(), Level::level58()] {
        let mut means = Vec::new();
        for &w in &WORKER_AXIS {
            let (m, s, p) = game_steps(&level, w.min(scale.workers.max(1)), w, scale);
            table.row(&[
                level.id.clone(),
                w.to_string(),
                format!("{m:.2}"),
                format!("{s:.2}"),
                format!("{p:.2}"),
            ]);
            means.push(m);
        }
        // Cross-worker variation: the paper reports σ 0.67 / 1.22.
        let sigma = std_dev(&means);
        table.row(&[
            level.id.clone(),
            "σ over workers".into(),
            format!("{sigma:.2}"),
            "-".into(),
            "-".into(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn game_steps_bounded_by_budget() {
        let scale = Scale {
            trials: 2,
            max_simulations: 10,
            rollout_limit: 5,
            ..Scale::quick()
        };
        let (m, s, p) = game_steps(&Level::level35(), 1, 2, &scale);
        assert!(m <= Level::level35().steps as f64);
        assert!(s >= 0.0);
        assert!((0.0..=1.0).contains(&p));
    }

    #[test]
    fn performance_table_has_rows_for_both_levels() {
        let scale = Scale {
            trials: 1,
            max_simulations: 6,
            rollout_limit: 4,
            ..Scale::quick()
        };
        // Restrict axis cost by running the full harness at minimal scale.
        let t = performance_retention(&scale);
        // 5 worker rows + 1 sigma row per level.
        assert_eq!(t.num_rows(), 12);
    }
}
