//! Fig. 5: episode reward and per-step planning time as the number of
//! simulation workers grows (4 / 8 / 16), for WU-UCT and the three
//! baselines on four games.
//!
//! The paper's claim: WU-UCT keeps its reward flat while getting faster;
//! baselines degrade in reward as parallelism rises.

use std::time::Duration;

use crate::env::{atari, Env, SlowEnv};
use crate::experiments::{eval_algo, rewards, Scale};
use crate::gameplay::EpisodeResult;
use crate::mcts::{LeafP, RootP, Search, TreeP, WuUct};
use crate::util::stats::{mean, std_dev};
use crate::util::table::{mean_pm_std, Table};

pub const WORKER_AXIS: [usize; 3] = [4, 8, 16];
pub const ALGOS: [&str; 4] = ["WU-UCT", "TreeP", "LeafP", "RootP"];

fn build(algo: &str, workers: usize, scale: &Scale, seed: u64) -> Box<dyn Search> {
    let spec = scale.atari_spec(seed);
    match algo {
        "WU-UCT" => Box::new(WuUct::new(spec, 1, workers)),
        "TreeP" => Box::new(TreeP::new(spec, workers, 1.0)),
        "LeafP" => Box::new(LeafP::new(spec, workers)),
        "RootP" => Box::new(RootP::new(spec, workers)),
        other => panic!("unknown fig-5 algorithm {other}"),
    }
}

/// One (game, algo, workers) cell: mean reward ± std and time/step.
pub fn cell(game: &str, algo: &str, workers: usize, scale: &Scale) -> (f64, f64, Duration) {
    let mut search = build(algo, workers, scale, scale.seed ^ workers as u64);
    let inner = atari::make(game, 1);
    let mut env: Box<dyn Env> = Box::new(SlowEnv::new(inner, scale.delay));
    let results: Vec<EpisodeResult> = eval_algo(search.as_mut(), env.as_mut(), scale);
    let rs = rewards(&results);
    let tps = results
        .iter()
        .map(|r| r.time_per_step)
        .sum::<Duration>()
        / results.len().max(1) as u32;
    (mean(&rs), std_dev(&rs), tps)
}

/// Full Fig. 5 harness over `games`.
pub fn run(games: &[&str], scale: &Scale) -> Table {
    let mut table = Table::new(
        format!(
            "Fig 5 — reward and time/step vs simulation workers ({} trials)",
            scale.trials
        ),
        &["Game", "Algo", "workers", "reward", "time/step"],
    );
    for &game in games {
        for &algo in &ALGOS {
            for &w in &WORKER_AXIS {
                let (m, s, tps) = cell(game, algo, w, scale);
                table.row(&[
                    game.to_string(),
                    algo.to_string(),
                    w.to_string(),
                    mean_pm_std(m, s),
                    format!("{tps:.2?}"),
                ]);
            }
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_produces_finite_metrics() {
        let scale = Scale {
            trials: 1,
            max_simulations: 6,
            rollout_limit: 4,
            max_episode_steps: 6,
            delay: Duration::from_micros(20),
            ..Scale::quick()
        };
        let (m, s, tps) = cell("Boxing", "WU-UCT", 2, &scale);
        assert!(m.is_finite());
        assert!(s >= 0.0);
        assert!(tps > Duration::ZERO);
    }

    #[test]
    fn table_covers_all_cells() {
        let scale = Scale {
            trials: 1,
            max_simulations: 4,
            rollout_limit: 3,
            max_episode_steps: 4,
            delay: Duration::from_micros(10),
            ..Scale::quick()
        };
        let t = run(&["Freeway"], &scale);
        assert_eq!(t.num_rows(), ALGOS.len() * WORKER_AXIS.len());
    }
}
