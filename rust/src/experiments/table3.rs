//! Table 3: WU-UCT speedup grid over (expansion workers × simulation
//! workers) ∈ {1,2,4,8,16}² on two tap-game levels, measured on the
//! latency-simulated emulator (see DESIGN.md §3).

use std::time::{Duration, Instant};

use crate::env::tapgame::{Level, TapGame};
use crate::env::SlowEnv;
use crate::experiments::Scale;
use crate::mcts::{Search, WuUct};
use crate::util::table::Table;

/// The paper's worker axis.
pub const WORKER_AXIS: [usize; 5] = [1, 2, 4, 8, 16];

/// Measured search time for a (level, Me, Ms) cell, averaged over repeats.
pub fn search_time(
    level: &Level,
    n_exp: usize,
    n_sim: usize,
    scale: &Scale,
    repeats: usize,
) -> Duration {
    let inner = TapGame::new(level.clone(), scale.seed ^ 0x9);
    let env = SlowEnv::new(Box::new(inner), scale.delay);
    let mut search = WuUct::new(scale.tap_spec(scale.seed), n_exp, n_sim);
    // One warmup search to populate thread pools / caches.
    search.search(&env);
    let t = Instant::now();
    for _ in 0..repeats {
        search.search(&env);
    }
    t.elapsed() / repeats as u32
}

/// Full speedup grid for one level: `grid[i][j]` = speedup of
/// (Me = AXIS[i], Ms = AXIS[j]) relative to (1, 1).
pub fn speedup_grid(level: &Level, scale: &Scale, repeats: usize) -> Vec<Vec<f64>> {
    let base = search_time(level, 1, 1, scale, repeats).as_secs_f64();
    WORKER_AXIS
        .iter()
        .map(|&me| {
            WORKER_AXIS
                .iter()
                .map(|&ms| base / search_time(level, me, ms, scale, repeats).as_secs_f64())
                .collect()
        })
        .collect()
}

/// Render the Table-3 shaped output for both levels.
pub fn run(scale: &Scale, repeats: usize) -> (Table, Vec<Vec<Vec<f64>>>) {
    let mut table = Table::new(
        format!(
            "Table 3 — WU-UCT speedup grid (Me x Ms), {} sims, {:?} emulator step",
            scale.max_simulations, scale.delay
        ),
        &["Level", "Me", "Ms=1", "Ms=2", "Ms=4", "Ms=8", "Ms=16"],
    );
    let mut grids = Vec::new();
    for level in [Level::level35(), Level::level58()] {
        let grid = speedup_grid(&level, scale, repeats);
        for (i, row) in grid.iter().enumerate() {
            let mut cells = vec![level.id.clone(), WORKER_AXIS[i].to_string()];
            cells.extend(row.iter().map(|s| format!("{s:.1}")));
            table.row(&cells);
        }
        grids.push(grid);
    }
    (table, grids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grid_shape_and_baseline() {
        // 2x2 sub-grid at tiny scale: check shape + the (1,1) cell ≈ 1.
        let scale = Scale {
            max_simulations: 8,
            rollout_limit: 4,
            delay: Duration::from_micros(200),
            ..Scale::quick()
        };
        let level = Level::level35();
        let base = search_time(&level, 1, 1, &scale, 1).as_secs_f64();
        assert!(base > 0.0);
        let s22 = search_time(&level, 2, 2, &scale, 1).as_secs_f64();
        assert!(s22 > 0.0);
    }

    #[test]
    fn more_sim_workers_speed_up_search() {
        let _serial = crate::util::timer::TIMING_TEST_LOCK.lock().unwrap();
        let scale = Scale {
            max_simulations: 16,
            rollout_limit: 8,
            delay: Duration::from_micros(400),
            ..Scale::quick()
        };
        let level = Level::level35();
        let t1 = search_time(&level, 1, 1, &scale, 2);
        let t8 = search_time(&level, 4, 8, &scale, 2);
        assert!(
            t8 < t1,
            "4x8 workers ({t8:?}) should beat 1x1 ({t1:?})"
        );
    }
}
