//! Fig. 2(b–c): time-consumption breakdown of WU-UCT's master process and
//! worker pools on the tap game and an Atari-like game.
//!
//! The paper's observations, which this harness verifies on our system:
//! simulation + expansion dominate even when parallelized; communication
//! overhead is negligible next to them; simulation workers run at
//! near-100% occupancy.

use crate::env::tapgame::{Level, TapGame};
use crate::env::{atari, Env, SlowEnv};
use crate::experiments::Scale;
use crate::mcts::{Search, WuUct};
use crate::util::table::Table;
use crate::util::timer::Breakdown;

/// Measured breakdown for one workload.
#[derive(Debug, Clone)]
pub struct BreakdownReport {
    pub workload: String,
    pub master: Breakdown,
    pub workers: Breakdown,
    pub sim_occupancy: f64,
}

/// Run `searches` consecutive WU-UCT searches and accumulate breakdowns.
pub fn measure(env: &dyn Env, scale: &Scale, searches: usize) -> BreakdownReport {
    let mut search = WuUct::new(
        scale.tap_spec(scale.seed ^ 0xf2),
        scale.workers,
        scale.workers,
    );
    let mut master = Breakdown::new();
    let mut workers = Breakdown::new();
    for _ in 0..searches {
        let r = search.search(env);
        master.merge(&r.master);
        workers.merge(&r.workers);
    }
    let sim_occupancy = workers.occupancy();
    BreakdownReport {
        workload: env.name().to_string(),
        master,
        workers,
        sim_occupancy,
    }
}

/// The two Fig. 2 workloads: tap Level-35 analogue + one Atari game.
pub fn run(scale: &Scale, searches: usize) -> (Table, Vec<BreakdownReport>) {
    let mut table = Table::new(
        format!(
            "Fig 2(b-c) — time breakdown, {} workers each pool",
            scale.workers
        ),
        &["Workload", "Side", "Phase", "seconds", "fraction"],
    );
    let mut reports = Vec::new();
    let envs: Vec<Box<dyn Env>> = vec![
        Box::new(SlowEnv::new(
            Box::new(TapGame::new(Level::level35(), scale.seed)),
            scale.delay,
        )),
        Box::new(SlowEnv::new(atari::make("SpaceInvaders", scale.seed), scale.delay)),
    ];
    for env in envs {
        let report = measure(env.as_ref(), scale, searches);
        for (side, b) in [("master", &report.master), ("workers", &report.workers)] {
            for (phase, secs, frac) in b.rows() {
                if secs == 0.0 {
                    continue;
                }
                table.row(&[
                    report.workload.clone(),
                    side.to_string(),
                    phase.to_string(),
                    format!("{secs:.4}"),
                    format!("{frac:.3}"),
                ]);
            }
        }
        table.row(&[
            report.workload.clone(),
            "workers".into(),
            "occupancy".into(),
            format!("{:.3}", report.sim_occupancy),
            "-".into(),
        ]);
        reports.push(report);
    }
    (table, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::Phase;
    use std::time::Duration;

    #[test]
    fn breakdown_shows_simulation_dominates_workers() {
        let scale = Scale {
            max_simulations: 16,
            rollout_limit: 8,
            workers: 4,
            delay: Duration::from_micros(150),
            ..Scale::quick()
        };
        let env = SlowEnv::new(
            Box::new(TapGame::new(Level::level35(), 1)),
            scale.delay,
        );
        let r = measure(&env, &scale, 2);
        let sim = r.workers.total(Phase::Simulation);
        let master_sel = r.master.total(Phase::Selection);
        assert!(
            sim > master_sel,
            "worker simulation time {sim:?} should dominate master selection {master_sel:?}"
        );
    }

    #[test]
    fn communication_negligible_vs_simulation() {
        let scale = Scale {
            max_simulations: 16,
            rollout_limit: 8,
            workers: 4,
            delay: Duration::from_micros(150),
            ..Scale::quick()
        };
        let env = SlowEnv::new(
            Box::new(TapGame::new(Level::level35(), 2)),
            scale.delay,
        );
        let r = measure(&env, &scale, 2);
        let comm = r.master.total(Phase::Communication);
        let sim = r.workers.total(Phase::Simulation);
        assert!(
            comm < sim,
            "communication {comm:?} should be far below simulation {sim:?}"
        );
    }
}
