//! Table 2 + Fig. 8: the pass-rate prediction system's evaluation.
//!
//! Table 2: paired t-tests of the 10-/100-rollout WU-UCT bots against the
//! (synthetic) player population across the eval levels — the 10-rollout
//! bot should be statistically indistinguishable from players, the
//! 100-rollout bot significantly better. Fig. 8: the MAE histogram
//! (paper: 8.6% MAE, 93% of levels under 20% error).

use crate::passrate::{run as run_system, Report, SystemConfig};
use crate::util::table::Table;

/// Run the system and format both artifacts.
pub fn run(cfg: &SystemConfig) -> anyhow::Result<(Table, Table, Report)> {
    let report = run_system(cfg)?;

    let mut t2 = Table::new(
        format!(
            "Table 2 — bot vs players, {} eval levels",
            report.errors.len()
        ),
        &["AI bot", "# rollouts", "Avg diff", "Effect size", "p-value"],
    );
    for &(budget, avg_diff, t) in &report.bot_vs_players {
        t2.row(&[
            "WU-UCT".into(),
            budget.to_string(),
            format!("{avg_diff:+.3}"),
            format!("{:.2}", t.effect_size.abs()),
            format!("{:.4}", t.p),
        ]);
    }

    let mut f8 = Table::new(
        format!(
            "Fig 8 — pass-rate prediction error (MAE {:.1}%, {:.0}% under 20%)",
            report.mae * 100.0,
            report.frac_under_20 * 100.0
        ),
        &["error bin", "levels"],
    );
    for (lo, count) in report.error_histogram() {
        f8.row(&[
            format!("{:.0}-{:.0}%", lo * 100.0, (lo + 0.05) * 100.0),
            count.to_string(),
        ]);
    }
    Ok((t2, f8, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_both_tables() {
        let cfg = SystemConfig::quick();
        let (t2, f8, report) = run(&cfg).unwrap();
        assert_eq!(t2.num_rows(), 2);
        assert_eq!(f8.num_rows(), 10);
        assert!(report.mae <= 0.5);
    }
}
