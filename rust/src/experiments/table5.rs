//! Table 5 (Appendix E): WU-UCT vs the TreeP variant that combines
//! virtual loss with virtual pseudo-count (Eq. 7), at r_VL = n_VL ∈
//! {1, 2, 3}, over 12 games.
//!
//! The paper's takeaway, which this harness lets you verify: no single
//! (r_VL, n_VL) works across tasks, while WU-UCT has no such knob at all.

use crate::env::atari;
use crate::experiments::{eval_algo, rewards, Scale};
use crate::mcts::{TreeP, WuUct};
use crate::util::stats::{mean, std_dev};
use crate::util::table::{mean_pm_std, Table};

/// The TreeP hyper-parameter settings of Table 5.
pub const TREEP_SETTINGS: [(f64, u32); 3] = [(1.0, 1), (2.0, 2), (3.0, 3)];

/// Run on `games`; returns the table plus per-game winner labels.
pub fn run(games: &[&str], scale: &Scale) -> (Table, Vec<String>) {
    let mut table = Table::new(
        format!(
            "Table 5 — WU-UCT vs TreeP(r_VL=n_VL) variants ({} trials)",
            scale.trials
        ),
        &["Environment", "WU-UCT", "TreeP r=n=1", "TreeP r=n=2", "TreeP r=n=3", "winner"],
    );
    let mut winners = Vec::new();
    for &game in games {
        let mut means = Vec::new();
        let mut cells = vec![game.to_string()];
        // WU-UCT column.
        {
            let mut s = WuUct::new(scale.atari_spec(scale.seed ^ 1), 1, scale.workers);
            let mut env = atari::make(game, 1);
            let rs = rewards(&eval_algo(&mut s, env.as_mut(), scale));
            means.push(mean(&rs));
            cells.push(mean_pm_std(mean(&rs), std_dev(&rs)));
        }
        for (i, &(r_vl, n_vl)) in TREEP_SETTINGS.iter().enumerate() {
            let mut s = TreeP::with_counts(
                scale.atari_spec(scale.seed ^ (i as u64 + 2)),
                scale.workers,
                r_vl,
                n_vl,
            );
            let mut env = atari::make(game, 1);
            let rs = rewards(&eval_algo(&mut s, env.as_mut(), scale));
            means.push(mean(&rs));
            cells.push(mean_pm_std(mean(&rs), std_dev(&rs)));
        }
        let labels = ["WU-UCT", "TreeP(1)", "TreeP(2)", "TreeP(3)"];
        let winner = labels[means
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()]
        .to_string();
        cells.push(winner.clone());
        table.row(&cells);
        winners.push(winner);
    }
    (table, winners)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_variants_on_one_game() {
        let scale = Scale {
            trials: 1,
            max_simulations: 6,
            rollout_limit: 4,
            max_episode_steps: 5,
            workers: 2,
            ..Scale::quick()
        };
        let (t, winners) = run(&["Boxing"], &scale);
        assert_eq!(t.num_rows(), 1);
        assert_eq!(winners.len(), 1);
    }
}
