//! Experiment harnesses — one per table/figure of the paper's evaluation.
//!
//! Every harness works at two scales: `Scale::quick()` (laptop, minutes)
//! and `Scale::paper()` (the paper's parameters). DESIGN.md §5 describes
//! the run-record conventions (which scale an archived run used). All
//! harnesses return a [`crate::util::table::Table`] whose rows mirror the
//! paper's.

pub mod fig10;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2_fig8;
pub mod table3;
pub mod table5;

use std::time::Duration;

use crate::env::Env;
use crate::gameplay::{play_episodes, EpisodeResult};
use crate::mcts::{Search, SearchSpec};

/// Workload scale for an experiment run.
#[derive(Debug, Clone)]
pub struct Scale {
    /// Episodes per (game, algorithm) cell.
    pub trials: usize,
    /// Simulations per search (paper: 128 Atari / 500 tap).
    pub max_simulations: u32,
    /// Rollout step bound (paper: 100).
    pub rollout_limit: u32,
    /// Cap on environment steps per episode.
    pub max_episode_steps: u32,
    /// Worker count for parallel algorithms (paper: 16).
    pub workers: usize,
    /// Per-step emulator latency for speedup experiments.
    pub delay: Duration,
    /// Base seed.
    pub seed: u64,
}

impl Scale {
    /// Laptop scale: minutes, preserves orderings and curve shapes.
    pub fn quick() -> Scale {
        Scale {
            trials: 3,
            max_simulations: 24,
            rollout_limit: 15,
            max_episode_steps: 40,
            workers: 8,
            delay: Duration::from_micros(120),
            seed: 0,
        }
    }

    /// The paper's parameters (hours on this container).
    pub fn paper() -> Scale {
        Scale {
            trials: 10,
            max_simulations: 128,
            rollout_limit: 100,
            max_episode_steps: 100_000,
            workers: 16,
            delay: Duration::from_micros(300),
            seed: 0,
        }
    }

    /// From the bench-scale environment knob.
    pub fn from_env() -> Scale {
        if crate::bench::paper_scale() {
            Scale::paper()
        } else {
            Scale::quick()
        }
    }

    /// Search spec for Atari-style experiments at this scale.
    pub fn atari_spec(&self, seed: u64) -> SearchSpec {
        SearchSpec {
            max_simulations: self.max_simulations,
            rollout_limit: self.rollout_limit,
            seed,
            ..SearchSpec::atari()
        }
    }

    /// Search spec for tap-game experiments at this scale.
    pub fn tap_spec(&self, seed: u64) -> SearchSpec {
        SearchSpec {
            max_simulations: self.max_simulations,
            rollout_limit: self.rollout_limit,
            seed,
            ..SearchSpec::tap_game()
        }
    }
}

/// Evaluate one algorithm on one environment: `trials` episodes.
pub fn eval_algo(
    search: &mut dyn Search,
    env: &mut dyn Env,
    scale: &Scale,
) -> Vec<EpisodeResult> {
    play_episodes(search, env, scale.seed, scale.trials, scale.max_episode_steps)
}

/// Rewards vector from episode results.
pub fn rewards(results: &[EpisodeResult]) -> Vec<f64> {
    results.iter().map(|r| r.total_reward).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_are_ordered() {
        let q = Scale::quick();
        let p = Scale::paper();
        assert!(q.trials < p.trials);
        assert!(q.max_simulations < p.max_simulations);
        assert!(p.workers >= 16);
    }

    #[test]
    fn specs_inherit_paper_shapes() {
        let s = Scale::quick();
        assert_eq!(s.tap_spec(0).max_width, 5);
        assert_eq!(s.atari_spec(0).max_width, 20);
    }
}
