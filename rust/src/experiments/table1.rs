//! Table 1: episode returns of WU-UCT vs TreeP / LeafP / RootP on the
//! 15-game suite, plus the sequential-UCT ceiling and the rollout-policy
//! floor ("PPO" column analogue), with Bonferroni-corrected paired t-tests.

use crate::env::atari;
use crate::eval::{HeuristicPolicy, RolloutPolicy};
use crate::experiments::{eval_algo, rewards, Scale};
use crate::mcts::{LeafP, RootP, Search, SequentialUct, TreeP, WuUct};
use crate::util::stats::{bonferroni_threshold, mean, paired_t_test, std_dev};
use crate::util::table::{mean_pm_std, Table};

/// Raw per-cell data, kept for Fig. 10's relative-performance bars.
#[derive(Debug, Clone)]
pub struct Table1Data {
    pub games: Vec<String>,
    /// rewards[game][algo] -> per-trial rewards; algo order = ALGOS.
    pub rewards: Vec<Vec<Vec<f64>>>,
}

/// Algorithm columns, in the paper's order.
pub const ALGOS: [&str; 6] = ["WU-UCT", "TreeP", "LeafP", "RootP", "Policy", "UCT"];

fn build_algo(name: &str, scale: &Scale, seed: u64) -> Option<Box<dyn Search>> {
    let spec = scale.atari_spec(seed);
    match name {
        // Paper: 16 simulation workers, 1 expansion worker for fairness.
        "WU-UCT" => Some(Box::new(WuUct::new(spec, 1, scale.workers))),
        "TreeP" => Some(Box::new(TreeP::new(spec, scale.workers, 1.0))),
        "LeafP" => Some(Box::new(LeafP::new(spec, scale.workers))),
        "RootP" => Some(Box::new(RootP::new(spec, scale.workers))),
        "UCT" => Some(Box::new(SequentialUct::new(spec))),
        "Policy" => None, // handled separately: no search at all
        other => panic!("unknown table-1 algorithm {other}"),
    }
}

/// Play episodes with the raw rollout policy (the "PPO" floor analogue).
fn policy_only_rewards(game: &str, scale: &Scale) -> Vec<f64> {
    (0..scale.trials)
        .map(|t| {
            let mut env = atari::make(game, 1);
            env.reset(scale.seed.wrapping_add(t as u64 * 7919));
            let mut policy = HeuristicPolicy::new(scale.seed ^ (t as u64));
            let mut total = 0.0;
            let mut steps = 0;
            while !env.is_terminal() && steps < scale.max_episode_steps {
                let a = policy.choose(env.as_ref());
                let r = env.step(a);
                total += r.reward;
                steps += 1;
                if r.done {
                    break;
                }
            }
            total
        })
        .collect()
}

/// Run the experiment over `games`.
pub fn run(games: &[&str], scale: &Scale) -> (Table, Table1Data) {
    let mut table = Table::new(
        format!(
            "Table 1 — episode return, {} workers, {} sims/step ({} trials)",
            scale.workers, scale.max_simulations, scale.trials
        ),
        &["Environment", "WU-UCT", "TreeP", "LeafP", "RootP", "Policy", "UCT", "sig"],
    );
    let threshold = bonferroni_threshold(0.05, games.len() * 3);
    let mut data = Table1Data { games: Vec::new(), rewards: Vec::new() };

    for &game in games {
        let mut per_algo: Vec<Vec<f64>> = Vec::with_capacity(ALGOS.len());
        for &algo in &ALGOS {
            let rs = match build_algo(algo, scale, scale.seed ^ fxhash(game) ^ fxhash(algo)) {
                Some(mut search) => {
                    let mut env = atari::make(game, 1);
                    rewards(&eval_algo(search.as_mut(), env.as_mut(), scale))
                }
                None => policy_only_rewards(game, scale),
            };
            per_algo.push(rs);
        }
        // Significance marks: WU-UCT vs TreeP (*), LeafP (†), RootP (‡).
        let wu = &per_algo[0];
        let mut marks = String::new();
        for (i, mark) in [(1usize, '*'), (2, '†'), (3, '‡')] {
            let t = paired_t_test(wu, &per_algo[i]);
            if t.p < threshold && mean(wu) > mean(&per_algo[i]) {
                marks.push(mark);
            }
        }
        let cells: Vec<String> = std::iter::once(game.to_string())
            .chain(per_algo.iter().map(|rs| mean_pm_std(mean(rs), std_dev(rs))))
            .chain(std::iter::once(if marks.is_empty() { "-".into() } else { marks }))
            .collect();
        table.row(&cells);
        data.games.push(game.to_string());
        data.rewards.push(per_algo);
    }
    (table, data)
}

/// Tiny deterministic string hash for per-cell seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale {
            trials: 2,
            max_simulations: 8,
            rollout_limit: 6,
            max_episode_steps: 10,
            workers: 2,
            ..Scale::quick()
        }
    }

    #[test]
    fn runs_on_two_games_with_full_columns() {
        let (table, data) = run(&["Boxing", "Freeway"], &tiny_scale());
        assert_eq!(table.num_rows(), 2);
        assert_eq!(data.games, vec!["Boxing", "Freeway"]);
        assert_eq!(data.rewards[0].len(), ALGOS.len());
        assert_eq!(data.rewards[0][0].len(), 2); // trials
    }

    #[test]
    fn policy_floor_is_deterministic_per_seed() {
        let s = tiny_scale();
        assert_eq!(policy_only_rewards("Boxing", &s), policy_only_rewards("Boxing", &s));
    }

    #[test]
    fn csv_export_works() {
        let (table, _) = run(&["Tennis"], &tiny_scale());
        let csv = table.to_csv();
        assert!(csv.contains("Tennis"));
        assert!(csv.lines().count() >= 2);
    }
}
