//! # WU-UCT: Watch the Unobserved in UCT
//!
//! A Rust + JAX + Pallas reproduction of *"Watch the Unobserved: A Simple
//! Approach to Parallelizing Monte Carlo Tree Search"* (Liu et al., ICLR
//! 2020).
//!
//! The library is organized as the paper's three-layer system:
//!
//! * **L3 (this crate)** — the WU-UCT master–worker coordinator
//!   ([`mcts::wu_uct`]), its baselines ([`mcts::sequential`],
//!   [`mcts::leafp`], [`mcts::treep`], [`mcts::rootp`]), the environment
//!   substrates ([`env`]) and every experiment harness ([`experiments`]).
//! * **L2/L1 (build-time Python)** — the distilled policy-value network
//!   (JAX) whose forward pass is a fused Pallas kernel, AOT-lowered to HLO
//!   text in `artifacts/` and executed from Rust via [`runtime`].
//! * **Service** — the multi-session search service ([`service`]): many
//!   concurrent WU-UCT sessions multiplexed over shared worker pools,
//!   behind a line-delimited JSON TCP protocol (`wu-uct serve`).
//!
//! See DESIGN.md for the system inventory, the experiment-record
//! conventions and the paper-vs-measured methodology.

pub mod bench;
pub mod env;
pub mod eval;
pub mod experiments;
pub mod gameplay;
pub mod mcts;
pub mod obs;
pub mod passrate;
pub mod runtime;
pub mod service;
pub mod store;
pub mod testkit;
pub mod tree;
pub mod util;
