//! Episode driver: run a full gameplay with a tree search planning each
//! step (Appendix D: "a tree search subroutine is called to plan for the
//! best action in each time step").

use std::time::{Duration, Instant};

use crate::env::Env;
use crate::mcts::Search;
use crate::util::timer::Breakdown;

/// Metrics of one episode.
#[derive(Debug, Clone)]
pub struct EpisodeResult {
    /// Undiscounted episode return (the paper's Table-1 metric).
    pub total_reward: f64,
    /// Environment steps taken (the tap game's "game steps").
    pub steps: u32,
    /// Wall-clock time per environment step (Fig. 5's speed metric).
    pub time_per_step: Duration,
    /// Whole-episode wall clock.
    pub elapsed: Duration,
    /// Summed master-side breakdown across searches.
    pub master: Breakdown,
    /// Summed worker-side breakdown across searches.
    pub workers: Breakdown,
    /// Tap game only: whether the level was passed (0 reward games: None).
    pub passed: Option<bool>,
}

/// Play one episode: search → act → repeat until terminal or `max_steps`.
pub fn play_episode(
    search: &mut dyn Search,
    env: &mut dyn Env,
    seed: u64,
    max_steps: u32,
) -> EpisodeResult {
    env.reset(seed);
    let start = Instant::now();
    let mut total_reward = 0.0;
    let mut steps = 0u32;
    let mut master = Breakdown::new();
    let mut workers = Breakdown::new();
    while !env.is_terminal() && steps < max_steps {
        let result = search.search(env);
        master.merge(&result.master);
        workers.merge(&result.workers);
        let legal = env.legal_actions();
        let action = if legal.contains(&result.best_action) {
            result.best_action
        } else {
            // Defensive: a search on a degenerate tree may return the
            // fallback action; never step illegally.
            legal[0]
        };
        let step = env.step(action);
        total_reward += step.reward;
        steps += 1;
        if step.done {
            break;
        }
    }
    let elapsed = start.elapsed();
    EpisodeResult {
        total_reward,
        steps,
        time_per_step: if steps > 0 { elapsed / steps } else { elapsed },
        elapsed,
        master,
        workers,
        passed: None,
    }
}

/// Play `n` episodes with distinct seeds; returns per-episode results.
pub fn play_episodes(
    search: &mut dyn Search,
    env: &mut dyn Env,
    base_seed: u64,
    n: usize,
    max_steps: u32,
) -> Vec<EpisodeResult> {
    (0..n)
        .map(|i| play_episode(search, env, base_seed.wrapping_add(i as u64 * 7919), max_steps))
        .collect()
}

/// Mean episode reward across results.
pub fn mean_reward(results: &[EpisodeResult]) -> f64 {
    crate::util::stats::mean(&results.iter().map(|r| r.total_reward).collect::<Vec<_>>())
}

/// Std-dev of episode rewards.
pub fn std_reward(results: &[EpisodeResult]) -> f64 {
    crate::util::stats::std_dev(&results.iter().map(|r| r.total_reward).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::garnet::Garnet;
    use crate::mcts::{SearchSpec, SequentialUct};

    fn quick_spec() -> SearchSpec {
        SearchSpec {
            max_simulations: 16,
            rollout_limit: 10,
            max_depth: 10,
            ..Default::default()
        }
    }

    #[test]
    fn episode_runs_to_termination() {
        let mut env = Garnet::new(12, 3, 15, 0.0, 1);
        let mut s = SequentialUct::new(quick_spec());
        let r = play_episode(&mut s, &mut env, 0, 100);
        assert!(r.steps > 0 && r.steps <= 15);
        assert!(r.total_reward.is_finite());
        assert!(env.is_terminal());
    }

    #[test]
    fn max_steps_caps_episode() {
        let mut env = Garnet::new(12, 3, 1000, 0.0, 2);
        let mut s = SequentialUct::new(quick_spec());
        let r = play_episode(&mut s, &mut env, 0, 5);
        assert_eq!(r.steps, 5);
    }

    #[test]
    fn multiple_episodes_distinct_seeds() {
        let mut env = Garnet::new(12, 3, 15, 0.0, 3);
        let mut s = SequentialUct::new(quick_spec());
        let rs = play_episodes(&mut s, &mut env, 0, 3, 100);
        assert_eq!(rs.len(), 3);
        let m = mean_reward(&rs);
        assert!(m.is_finite());
        assert!(std_reward(&rs) >= 0.0);
    }

    #[test]
    fn search_achieves_near_optimal_return() {
        // Exact value iteration gives the optimal 12-step return; planning
        // with a decent budget should collect a large fraction of it.
        let env0 = Garnet::new(15, 4, 12, 0.0, 77);
        let optimal = env0.optimal_value(0, 12);
        let mut env = env0.clone();
        let mut s = SequentialUct::new(SearchSpec {
            max_simulations: 120,
            rollout_limit: 12,
            max_depth: 12,
            gamma: 1.0,
            seed: 5,
            ..Default::default()
        });
        let got = play_episode(&mut s, &mut env, 0, 100).total_reward;
        assert!(
            got >= 0.6 * optimal,
            "search return {got:.3} below 60% of optimal {optimal:.3}"
        );
    }
}
