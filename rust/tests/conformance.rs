//! Cross-algorithm conformance: every entry in `mcts::ALGORITHMS` must
//! identify the optimal root action of a seeded Garnet MDP within a fixed
//! simulation budget, and WU-UCT's chosen action must be invariant to
//! worker count under testkit-scripted latencies.
//!
//! ## Why the test MDP is *constructed*, not just seeded
//!
//! This repo's tree policy scores a child by its value `V` (the mean
//! return observed **from the child's state onward**) — edge rewards are
//! folded into the parent during backpropagation, matching Eqs. 2–4 of
//! the paper. A random MDP whose optimal arm is optimal only because of
//! its immediate edge reward is therefore not identifiable by *any* of
//! the implemented algorithms, sequential UCT included. The scan below
//! searches seeds for a 2-step Garnet in which
//!
//! 1. every return sample any algorithm can ever back up through arm `a`
//!    lies in a computable interval `I_a` (rollouts contribute
//!    `0.5·r(s_a,b) + 0.5·h(s_a)` for some action `b`; grandchild
//!    short-circuits contribute `r(s_a,b)`), and
//! 2. one arm's interval sits ≥ 0.2 above every other arm's, and
//! 3. that same arm is the `Q*`-optimal root action (checked against the
//!    Garnet's exact value iteration).
//!
//! Interval separation makes the test deterministic *by construction*:
//! whatever rollout mixture a scheduler produces, the optimal arm's
//! empirical value exceeds every competitor's, so UCB visit counts must
//! concentrate on it within the budget — for every algorithm and every
//! worker count.

use wu_uct::env::garnet::Garnet;
use wu_uct::env::Env;
use wu_uct::mcts::{by_name, SearchSpec, ALGORITHMS};
use wu_uct::testkit::{scripted_search, LatencyScript};

const ARMS: usize = 3;
const HORIZON: u32 = 2;
/// Minimum gap between the optimal arm's value interval and the others'.
const MARGIN: f64 = 0.2;

/// Value-interval bounds for one root arm (see module docs).
fn arm_interval(env: &Garnet, arm: usize) -> (f64, f64) {
    let mut e = env.clone();
    e.step(arm);
    let h = e.heuristic_value();
    let rewards: Vec<f64> = (0..ARMS).map(|b| e.action_heuristic(b)).collect();
    let r_min = rewards.iter().cloned().fold(f64::MAX, f64::min);
    let r_max = rewards.iter().cloned().fold(f64::MIN, f64::max);
    let lo = f64::min(r_min, 0.5 * r_min + 0.5 * h);
    let hi = f64::max(r_max, 0.5 * r_max + 0.5 * h);
    (lo, hi)
}

/// Scan seeds for a Garnet whose optimal arm is identifiable by interval
/// separation AND optimal under exact `Q*`. Deterministic: the scan order
/// is fixed, so every run tests the same MDP. Memoized across tests.
fn find_separated_garnet() -> (Garnet, usize) {
    static FOUND: std::sync::OnceLock<(u64, usize)> = std::sync::OnceLock::new();
    let &(seed, arm) = FOUND.get_or_init(scan);
    (Garnet::new(40, ARMS, HORIZON, 0.0, seed), arm)
}

/// The scan body: returns `(garnet seed, optimal arm)`.
fn scan() -> (u64, usize) {
    for seed in 0..500_000u64 {
        let env = Garnet::new(40, ARMS, HORIZON, 0.0, seed);
        if env.is_terminal() {
            continue;
        }
        let intervals: Vec<(f64, f64)> = (0..ARMS).map(|a| arm_interval(&env, a)).collect();
        let Some(best) = (0..ARMS).find(|&a| {
            (0..ARMS).all(|b| b == a || intervals[a].0 >= intervals[b].1 + MARGIN)
        }) else {
            continue;
        };
        // RootP ranks arms by `r(s0,a) + γ·V̂(s_a)` rather than by visit
        // counts, so the separated arm must also win that criterion for
        // every realizable subtree value — fold the edge rewards in.
        let r0: Vec<f64> = (0..ARMS).map(|a| env.action_heuristic(a)).collect();
        let gamma = SearchSpec::default().gamma;
        let rootp_aligned = (0..ARMS).all(|b| {
            b == best
                || r0[best] + gamma * intervals[best].0 >= r0[b] + gamma * intervals[b].1 + 0.05
        });
        if !rootp_aligned {
            continue;
        }
        // Ground truth: the separated arm must also be Q*-optimal with a
        // clear gap, so "identifies the optimal root action" is a claim
        // about the MDP, not about this repo's value convention.
        let q: Vec<f64> = (0..ARMS).map(|a| env.q_star(a, HORIZON)).collect();
        let q_best = q[best];
        let runner_up = (0..ARMS)
            .filter(|&a| a != best)
            .map(|a| q[a])
            .fold(f64::MIN, f64::max);
        if q_best >= runner_up + 0.05 {
            return (seed, best);
        }
    }
    panic!("no interval-separated Garnet found in the scan range");
}

fn conformance_spec(sims: u32, seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: sims,
        rollout_limit: 4,
        max_depth: 8,
        // A soft exploration coefficient keeps UCB's forced exploration
        // of the separated suboptimal arms to ~2·ln(T)·β²/gap² ≈ 20
        // visits each, so the optimal arm dominates the visit count well
        // within the budget.
        beta: 0.25,
        seed,
        ..SearchSpec::default()
    }
}

#[test]
fn every_algorithm_identifies_the_optimal_root_action() {
    let (env, optimal) = find_separated_garnet();
    for name in ALGORITHMS {
        let mut search = by_name(name, conformance_spec(600, 17), 2).unwrap();
        let r = search.search(&env);
        assert!(r.simulations >= 600, "{name} under-spent its budget");
        assert_eq!(
            r.best_action, optimal,
            "{name} missed the optimal root action (chose {}, Q* favors {optimal})",
            r.best_action
        );
    }
}

#[test]
fn wu_uct_chosen_action_is_invariant_to_worker_count() {
    // The paper's claim, made replayable: under scripted latencies, the
    // WU-UCT driver must pick the same (optimal) root action no matter
    // how many virtual workers execute its rollouts — even though the
    // schedules themselves differ materially across worker counts.
    let (env, optimal) = find_separated_garnet();
    let script = LatencyScript::uniform(99, (1, 3), (2, 11));
    let mut traces = Vec::new();
    for (exp, sim) in [(1, 1), (1, 2), (2, 4), (4, 8), (4, 16)] {
        let out = scripted_search(conformance_spec(400, 23), &env, exp, sim, script);
        assert_eq!(out.completed, 400, "(exp={exp}, sim={sim}) under-spent");
        assert_eq!(
            out.best_action, optimal,
            "worker count (exp={exp}, sim={sim}) changed the chosen action"
        );
        traces.push(out.trace);
    }
    // The invariance claim is only meaningful if the schedules differed.
    assert!(
        traces.windows(2).any(|w| w[0] != w[1]),
        "every worker count produced an identical schedule — the sweep tests nothing"
    );
}

#[test]
fn scripted_conformance_searches_replay_identically() {
    // Acceptance criterion: same seed ⇒ identical golden trace.
    let (env, _) = find_separated_garnet();
    let script = LatencyScript::uniform(7, (1, 4), (1, 9));
    let a = scripted_search(conformance_spec(200, 3), &env, 2, 4, script);
    let b = scripted_search(conformance_spec(200, 3), &env, 2, 4, script);
    assert_eq!(a.best_action, b.best_action);
    assert_eq!(a.ticks, b.ticks);
    assert_eq!(a.trace, b.trace);
}
