//! Store subsystem integration tests: codec robustness (fuzzed, typed
//! errors, never a panic — full images and `DeltaImage`s alike), WAL
//! replay/rotation/torn-tail semantics, live crash-recovery through the
//! sharded service (including through live delta chains), live
//! migration + rebalancing, and the deterministic testkit acceptance
//! proofs — scripted crash at every think boundary *and batch position*
//! recovering the control run's exact tree, group commit provably
//! batching N sessions onto few fsyncs against the live scheduler, the
//! quiet-fleet zero-byte checkpoint regression, and migrate-under-load
//! preserving `ΣO = 0` plus the control run's `best` action.

use std::fs;
use std::io::Write as _;
use std::path::PathBuf;

use wu_uct::env::garnet::Garnet;
use wu_uct::env::Env;
use wu_uct::mcts::SearchSpec;
use wu_uct::obs::{
    list_flight_segments, read_flight_segment, replay_flight, replay_flight_tree, Event,
    EventKind,
};
use wu_uct::service::proto::make_env;
use wu_uct::service::{
    RebalanceConfig, SearchService, ServiceConfig, SessionOptions, ShardedConfig,
    ShardedService,
};
use wu_uct::store::codec::{DeltaImage, SessionImage, SessionMeta};
use wu_uct::store::wal::{read_segment, Record, StoreConfig, Wal};
use wu_uct::store::{Error, SessionStore};
use wu_uct::testkit::{
    migrate_under_load, scripted_driver, DurableScriptedService, LatencyScript, ScriptedDisk,
    ScriptedService, ScriptedStore,
};
use wu_uct::tree::Tree;
use wu_uct::util::rng::Pcg32;

/// Fresh per-test scratch directory (unique name, wiped on entry).
fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("wuuct-store-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn spec(sims: u32, seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: sims,
        rollout_limit: 8,
        max_depth: 12,
        seed,
        ..SearchSpec::default()
    }
}

/// Must match proto's `make_env("garnet", seed)` construction so images
/// revive bit-exactly.
fn garnet(seed: u64) -> Garnet {
    Garnet::new(15, 3, 30, 0.0, seed)
}

fn opts(env_seed: u64) -> SessionOptions {
    SessionOptions { env_seed, ..SessionOptions::default() }
}

/// A session image with real searched statistics, deterministic in seed.
fn searched_image(session: u64, seed: u64) -> SessionImage {
    let env = garnet(seed);
    let driver = scripted_driver(
        spec(32, seed),
        &env,
        2,
        4,
        LatencyScript::uniform(seed, (1, 3), (2, 9)),
    );
    let meta = SessionMeta { env_seed: seed, ..SessionMeta::default() };
    SessionImage::capture(session, &driver, meta).expect("idle driver is quiescent")
}

/// Per-node fingerprint: bit-exact comparison handle for whole trees.
fn fingerprint(tree: &Tree) -> Vec<(Option<usize>, usize, u32, u64, f64, f64, u32)> {
    tree.iter()
        .map(|(_, n)| (n.parent, n.action, n.n, n.o as u64, n.v, n.reward, n.depth))
        .collect()
}

// ---------------------------------------------------------------------
// Codec robustness
// ---------------------------------------------------------------------

#[test]
fn searched_image_roundtrips_and_revives_identically() {
    let img = searched_image(9, 4);
    let bytes = img.encode().unwrap();
    let back = SessionImage::decode(&bytes).unwrap();
    assert_eq!(back.encode().unwrap(), bytes, "decode∘encode is the identity");
    assert_eq!(fingerprint(&back.tree), fingerprint(&img.tree));
    let original_best = img.tree.best_root_action();
    let driver = back.into_driver(make_env).unwrap();
    assert_eq!(driver.tree().best_root_action(), original_best);
    assert_eq!(driver.tree().total_unobserved(), 0);
    assert_eq!(driver.env().name(), "garnet");
}

#[test]
fn unknown_env_in_an_image_is_a_typed_error() {
    let mut img = searched_image(1, 2);
    img.env_name = "not-a-real-env".into();
    let bytes = img.encode().unwrap();
    let back = SessionImage::decode(&bytes).unwrap();
    match back.into_driver(make_env) {
        Err(Error::UnknownEnv { name }) => assert_eq!(name, "not-a-real-env"),
        other => panic!("expected UnknownEnv, got {:?}", other.map(|_| ())),
    }
}

/// Fuzz: random byte/bit mutations of a valid image must always come
/// back as `Ok` or a typed `Err` — never a panic. The checksummed frame
/// means essentially every mutation is rejected (the version field is
/// the one byte where a downgrade can legally still parse).
#[test]
fn fuzzed_image_mutations_never_panic() {
    let bytes = searched_image(3, 7).encode().unwrap();
    let mut rng = Pcg32::new(0xF022);
    let mut accepted = 0u32;
    for _ in 0..400 {
        let mut mutated = bytes.clone();
        match rng.below(3) {
            0 => {
                // Single bit flip.
                let i = rng.below_usize(mutated.len());
                mutated[i] ^= 1 << rng.below(8);
            }
            1 => {
                // Truncate at a random point.
                mutated.truncate(rng.below_usize(mutated.len()));
            }
            _ => {
                // Overwrite a random short run.
                let i = rng.below_usize(mutated.len());
                let n = (rng.below_usize(16) + 1).min(mutated.len() - i);
                for b in &mut mutated[i..i + n] {
                    *b = (rng.below(256)) as u8;
                }
            }
        }
        if SessionImage::decode(&mutated).is_ok() {
            accepted += 1;
        }
    }
    assert!(
        accepted <= 8,
        "checksummed frames should reject nearly all mutations, accepted {accepted}/400"
    );
}

// ---------------------------------------------------------------------
// WAL semantics
// ---------------------------------------------------------------------

fn image_bytes(session: u64, seed: u64) -> Vec<u8> {
    searched_image(session, seed).encode().unwrap()
}

#[test]
fn wal_replay_reconstructs_open_advance_snapshot_close() {
    let dir = temp_dir("replay");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 10) }).unwrap();
        wal.append(&Record::Open { session: 2, image: image_bytes(2, 20) }).unwrap();
        wal.append(&Record::Advance { session: 1, action: 2 }).unwrap();
        wal.append(&Record::Advance { session: 1, action: 0 }).unwrap();
        wal.append(&Record::Snapshot { session: 2, image: image_bytes(2, 21) }).unwrap();
        wal.append(&Record::Close { session: 2 }).unwrap();
    }
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert!(!recovery.torn_tail);
    assert_eq!(recovery.records, 6);
    assert_eq!(recovery.sessions.len(), 1, "session 2 closed");
    let rs = &recovery.sessions[0];
    assert_eq!(rs.image.session, 1);
    assert_eq!(rs.advances, vec![2, 0], "advances replay in order");
}

#[test]
fn wal_snapshot_clears_prior_advances() {
    let dir = temp_dir("snapshot-clears");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 5, image: image_bytes(5, 1) }).unwrap();
        wal.append(&Record::Advance { session: 5, action: 1 }).unwrap();
        wal.append(&Record::Snapshot { session: 5, image: image_bytes(5, 2) }).unwrap();
        wal.append(&Record::Advance { session: 5, action: 2 }).unwrap();
    }
    let (_, recovery) = Wal::open(&cfg).unwrap();
    let rs = &recovery.sessions[0];
    assert_eq!(rs.advances, vec![2], "only post-snapshot advances replay");
}

#[test]
fn wal_checkpoint_rotates_and_purges_old_segments() {
    let dir = temp_dir("checkpoint");
    let cfg = StoreConfig { max_segment_bytes: 1, ..StoreConfig::new(&dir) };
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 3) }).unwrap();
        wal.append(&Record::Advance { session: 1, action: 0 }).unwrap();
        assert!(wal.needs_checkpoint(), "1-byte budget is always exceeded");
        let out = wal.checkpoint(vec![(1, image_bytes(1, 4))], &[]).unwrap();
        assert_eq!(out.purged, 1, "the pre-checkpoint segment is deleted");
        assert!(!out.skipped);
        assert!(out.bytes_rewritten > 0);
        assert_eq!(wal.segment_index(), 2);
    }
    let segments: Vec<_> = fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert_eq!(segments, vec!["wal-00000002.log".to_string()]);
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert_eq!(recovery.sessions.len(), 1);
    assert_eq!(recovery.sessions[0].image.session, 1);
    assert!(recovery.sessions[0].advances.is_empty(), "checkpoint folded the advance in");
}

#[test]
fn torn_tail_is_tolerated_and_repaired_but_is_a_typed_error_when_strict() {
    let dir = temp_dir("torn");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 5) }).unwrap();
    }
    let seg = dir.join("wal-00000001.log");
    // Simulate a crash mid-append: a partial frame at the tail.
    {
        let mut f = fs::OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&[42, 0, 0]).unwrap();
    }
    // Strict read: typed truncation error, no panic.
    match read_segment(&seg, false) {
        Err(Error::Truncated { .. }) => {}
        other => panic!("expected Truncated, got {:?}", other.map(|_| ())),
    }
    // Tolerant read keeps the valid prefix and reports the tear.
    let read = read_segment(&seg, true).unwrap();
    assert_eq!(read.records.len(), 1);
    assert!(read.torn_at.is_some());
    // Full recovery tolerates (it is the last segment), repairs the
    // file, and still recovers the session.
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert!(recovery.torn_tail);
    assert_eq!(recovery.sessions.len(), 1);
    // The repair truncated the partial record: a further boot is clean.
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert!(!recovery.torn_tail, "torn tail must not survive the repair");
    assert_eq!(recovery.sessions.len(), 1);
}

#[test]
fn corrupt_wal_record_is_a_checksum_error_even_when_tolerant() {
    let dir = temp_dir("corrupt");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 6) }).unwrap();
        wal.append(&Record::Close { session: 1 }).unwrap();
    }
    let seg = dir.join("wal-00000001.log");
    let mut data = fs::read(&seg).unwrap();
    let mid = data.len() / 2;
    data[mid] ^= 0x10;
    fs::write(&seg, &data).unwrap();
    assert!(matches!(
        read_segment(&seg, true),
        Err(Error::ChecksumMismatch { .. })
    ));
    assert!(Wal::open(&cfg).is_err(), "recovery must refuse corrupt records");
}

/// A checksum failure on the *final* record of the final segment is the
/// other face of a torn write (header sector persisted, body garbage):
/// tolerated and truncated, while the same damage mid-segment stays a
/// hard error (previous test).
#[test]
fn corrupt_final_record_is_treated_as_a_torn_tail() {
    let dir = temp_dir("corrupt-tail");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 6) }).unwrap();
        wal.append(&Record::Close { session: 1 }).unwrap();
    }
    let seg = dir.join("wal-00000001.log");
    let mut data = fs::read(&seg).unwrap();
    let last = data.len() - 1; // inside the trailing Close record's body
    data[last] ^= 0x10;
    fs::write(&seg, &data).unwrap();
    assert!(matches!(
        read_segment(&seg, false),
        Err(Error::ChecksumMismatch { .. })
    ));
    let read = read_segment(&seg, true).unwrap();
    assert_eq!(read.records.len(), 1, "the valid prefix survives");
    assert!(read.torn_at.is_some());
    // Recovery tolerates, repairs, and resurrects the session (the torn
    // Close never committed).
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert!(recovery.torn_tail);
    assert_eq!(recovery.sessions.len(), 1);
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert!(!recovery.torn_tail, "repair removed the damaged record");
}

/// Checkpointing does not need a globally idle shard: sessions that are
/// mid-think have their latest durable image + advances carried forward
/// from the old segments before those are purged.
#[test]
fn checkpoint_carries_unimageable_sessions_forward() {
    let dir = temp_dir("checkpoint-carry");
    let cfg = StoreConfig { max_segment_bytes: 1, ..StoreConfig::new(&dir) };
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: image_bytes(1, 30) }).unwrap();
        wal.append(&Record::Advance { session: 1, action: 2 }).unwrap();
        wal.append(&Record::Open { session: 2, image: image_bytes(2, 31) }).unwrap();
        // Session 1 is "mid-think": carried; session 2 snapshots fresh.
        let out = wal
            .checkpoint(vec![(2, image_bytes(2, 32))], &[1])
            .unwrap();
        assert_eq!(out.purged, 1);
    }
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert_eq!(recovery.sessions.len(), 2);
    let one = &recovery.sessions[0];
    assert_eq!(one.image.session, 1);
    assert_eq!(one.advances, vec![2], "carried advances survive the purge");
    let two = &recovery.sessions[1];
    assert_eq!(two.image.session, 2);
    assert!(two.advances.is_empty());
    // A carry id with no durable state must refuse to purge history.
    let (mut wal, _) = Wal::open(&cfg).unwrap();
    assert!(matches!(
        wal.checkpoint(Vec::new(), &[99]),
        Err(Error::Corrupt { .. })
    ));
}

/// A crash between a migration's target `Open` and source `Close`
/// leaves the session on two shards; recovery must keep exactly one
/// copy — the most advanced — and durably forget the other.
#[test]
fn duplicated_sessions_after_a_migration_crash_are_deduped_on_recovery() {
    let dir = temp_dir("dedup");
    let sid = 9_001u64;
    {
        // Hand-craft the crash state: the stale copy (fewer thinks) on
        // shard 0, the fresh copy on shard 1.
        let mut stale = searched_image(sid, 40);
        stale.meta.thinks = 1;
        let mut fresh = searched_image(sid, 40);
        fresh.meta.thinks = 2;
        let (mut wal0, _) = Wal::open(&StoreConfig::new(dir.join("shard-0"))).unwrap();
        wal0.append(&Record::Open { session: sid, image: stale.encode().unwrap() })
            .unwrap();
        let (mut wal1, _) = Wal::open(&StoreConfig::new(dir.join("shard-1"))).unwrap();
        wal1.append(&Record::Open { session: sid, image: fresh.encode().unwrap() })
            .unwrap();
    }
    let svc = ShardedService::start_durable(durable_cfg(2, &dir)).unwrap();
    let h = svc.handle();
    let m = h.metrics().unwrap();
    assert_eq!(m.sessions_open, 1, "exactly one copy survives dedup");
    assert_eq!(m.sessions_recovered, 2, "both shards replayed a copy");
    assert_eq!(h.shard_of(sid), 1, "the most-advanced copy (more thinks) wins");
    let t = h.think(sid, 8).unwrap();
    assert!(t.quiescent);
    h.close(sid).unwrap();
    drop(svc);
    // The dedup was durable: a further restart sees a single (now
    // closed) history, no resurrection on shard 0.
    let svc = ShardedService::start_durable(durable_cfg(2, &dir)).unwrap();
    assert_eq!(svc.handle().metrics().unwrap().sessions_open, 0);
}

#[test]
fn future_version_wal_segment_and_image_are_rejected() {
    // Future segment version.
    let dir = temp_dir("future-seg");
    let cfg = StoreConfig::new(&dir);
    {
        let _ = Wal::open(&cfg).unwrap();
    }
    let seg = dir.join("wal-00000001.log");
    let mut data = fs::read(&seg).unwrap();
    data[8] = 0xEE; // version low byte
    fs::write(&seg, &data).unwrap();
    assert!(matches!(
        read_segment(&seg, true),
        Err(Error::UnsupportedVersion { .. })
    ));
    assert!(Wal::open(&cfg).is_err());

    // Future image version inside an otherwise-valid record.
    let dir = temp_dir("future-img");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        let mut image = image_bytes(1, 7);
        image[4] = 0xEE; // image version low byte
        wal.append(&Record::Open { session: 1, image }).unwrap();
    }
    match Wal::open(&cfg) {
        Err(Error::UnsupportedVersion { found, .. }) => assert_eq!(found, 0xEE),
        other => panic!("expected UnsupportedVersion, got {:?}", other.map(|_| ())),
    }
}

// ---------------------------------------------------------------------
// Live service: crash recovery, migration, rebalancing
// ---------------------------------------------------------------------

fn durable_cfg(shards: usize, dir: &std::path::Path) -> ShardedConfig {
    ShardedConfig {
        shards,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        data_dir: Some(dir.to_path_buf()),
        ..ShardedConfig::default()
    }
}

#[test]
fn killed_service_recovers_every_session_and_resumes() {
    let dir = temp_dir("live-recover");
    let (sid, best_before, steps_before) = {
        let svc = ShardedService::start_durable(durable_cfg(1, &dir)).unwrap();
        let h = svc.handle();
        let sid = h.open(Box::new(garnet(5)), spec(24, 5), opts(5)).unwrap();
        let t = h.think(sid, 0).unwrap();
        assert!(t.quiescent);
        let adv = h.advance(sid, t.action).unwrap();
        let t2 = h.think(sid, 0).unwrap();
        assert!(t2.quiescent);
        (sid, h.best_action(sid).unwrap(), adv.steps)
        // svc dropped without close: the WAL's view of a SIGKILL.
    };
    let svc = ShardedService::start_durable(durable_cfg(1, &dir)).unwrap();
    let h = svc.handle();
    let m = h.metrics().unwrap();
    assert_eq!(m.sessions_recovered, 1);
    assert_eq!(m.sessions_open, 1);
    assert_eq!(
        h.best_action(sid).unwrap(),
        best_before,
        "recovered tree must reproduce the pre-crash recommendation"
    );
    // The session resumes: searching and stepping keep working.
    let t3 = h.think(sid, 0).unwrap();
    assert!(t3.quiescent);
    let adv = h.advance(sid, t3.action).unwrap();
    assert_eq!(adv.steps, steps_before + 1, "step counter survived the crash");
    let c = h.close(sid).unwrap();
    assert_eq!(c.unobserved, 0);
    assert_eq!(c.thinks, 3, "think counter survived the crash");
}

#[test]
fn recovery_restores_migration_overrides() {
    let dir = temp_dir("live-migrate-recover");
    let (sid, target) = {
        let svc = ShardedService::start_durable(durable_cfg(2, &dir)).unwrap();
        let h = svc.handle();
        let sid = h.open(Box::new(garnet(8)), spec(16, 8), opts(8)).unwrap();
        h.think(sid, 0).unwrap();
        let target = 1 - h.shard_of(sid);
        let outcome = h.migrate(sid, target).unwrap();
        assert!(outcome.moved);
        (sid, target)
    };
    let svc = ShardedService::start_durable(durable_cfg(2, &dir)).unwrap();
    let h = svc.handle();
    assert_eq!(
        h.shard_of(sid),
        target,
        "router must relearn the migrated session's home from the WALs"
    );
    let t = h.think(sid, 0).unwrap();
    assert!(t.quiescent);
    h.close(sid).unwrap();
    // New opens must not collide with the recovered id.
    let fresh = h.open(Box::new(garnet(9)), spec(16, 9), opts(9)).unwrap();
    assert_ne!(fresh, sid);
    h.close(fresh).unwrap();
}

/// Retry an op that may transiently observe the typed `Recovering`
/// error while the background rebalancer holds the session mid-flight.
/// ("unknown session" is the same race seen from the narrow window
/// where the export has landed but the mover has not yet claimed the
/// migrating set against this op's route check.)
fn with_recovering_retry<T>(mut op: impl FnMut() -> anyhow::Result<T>) -> T {
    for _ in 0..400 {
        match op() {
            Ok(v) => return v,
            Err(e)
                if e.downcast_ref::<wu_uct::store::Recovering>().is_some()
                    || e.to_string().contains("unknown session") =>
            {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => panic!("op failed non-transiently: {e:#}"),
        }
    }
    panic!("session stuck in recovering state");
}

#[test]
fn background_rebalancer_drains_skew() {
    let svc = ShardedService::start_durable(ShardedConfig {
        shards: 2,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..ServiceConfig::default()
        },
        rebalance: Some(RebalanceConfig {
            max_skew: 1.2,
            interval: std::time::Duration::from_millis(30),
        }),
        ..ShardedConfig::default()
    })
    .unwrap();
    let h = svc.handle();
    let mut sids = Vec::new();
    for i in 0..12u64 {
        sids.push(h.open(Box::new(garnet(i)), spec(8, i), opts(i)).unwrap());
    }
    // Empty the less-loaded shard to force maximal skew, then let the
    // background pass work (the survivor holds ≥ 6 sessions, so at
    // least two must migrate for the occupancies to meet).
    let occ = h.shard_sessions().unwrap();
    let drain = if occ[0].len() <= occ[1].len() { 0 } else { 1 };
    for &sid in &sids {
        if h.shard_of(sid) == drain {
            with_recovering_retry(|| h.close(sid));
        }
    }
    let mut balanced = false;
    for _ in 0..200 {
        let occ = h.shard_sessions().unwrap();
        if occ[0].len().abs_diff(occ[1].len()) <= 1 {
            balanced = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let occ = h.shard_sessions().unwrap();
    assert!(balanced, "rebalancer left occupancy {occ:?}");
    let m = h.metrics().unwrap();
    assert!(m.migrations_in >= 1, "balancing must have migrated sessions");
    for shard in occ {
        for sid in shard {
            let t = with_recovering_retry(|| h.think(sid, 4));
            assert!(t.quiescent, "ΣO = 0 for every session after rebalancing");
            with_recovering_retry(|| h.close(sid));
        }
    }
}

// ---------------------------------------------------------------------
// Deterministic acceptance proofs (testkit, virtual time)
// ---------------------------------------------------------------------

/// Crash-recovery invariant, proven deterministically: scripted crash at
/// every think/advance boundary, followed by replay, yields a session
/// whose tree equals the control run's tree at that boundary — the last
/// quiescent snapshot plus the replayed advance records, node for node.
#[test]
fn scripted_crash_at_every_boundary_recovers_the_control_tree() {
    const ROUNDS: usize = 3;
    let seed = 11u64;
    let script = LatencyScript::uniform(seed, (1, 3), (2, 7));
    let sp = spec(16, seed);
    let env = garnet(sp.seed); // durable convention: env seed == spec seed

    // Control run: record the tree at every boundary (post-think and
    // post-advance of each round).
    let mut control = ScriptedService::new(1, 2, script);
    control.open(1, &env, sp.clone(), 1.0);
    let mut control_fps = Vec::new();
    for _ in 0..ROUNDS {
        control.begin_think(1, 16);
        control.run_to_completion();
        control_fps.push(fingerprint(control.driver(1).tree()));
        let best = control.best_action(1);
        control.advance(1, best).unwrap();
        control_fps.push(fingerprint(control.driver(1).tree()));
    }

    // Crash runs: same schedule, crash after boundary k, recover, compare.
    for k in 0..control_fps.len() {
        let dir = temp_dir(&format!("scripted-crash-{k}"));
        let cfg = StoreConfig::new(&dir);
        let mut svc = DurableScriptedService::create(1, 2, script, &cfg).unwrap();
        svc.open(1, &env, sp.clone(), 1.0).unwrap();
        let mut boundary = 0;
        'schedule: for _ in 0..ROUNDS {
            svc.begin_think(1, 16);
            svc.run().unwrap();
            if boundary == k {
                break 'schedule;
            }
            boundary += 1;
            let best = svc.best_action(1);
            svc.advance(1, best).unwrap();
            if boundary == k {
                break 'schedule;
            }
            boundary += 1;
        }
        svc.crash();
        let (recovered, count) = DurableScriptedService::recover(1, 2, script, &cfg).unwrap();
        assert_eq!(count, 1, "crash at boundary {k} lost the session");
        assert!(recovered.quiescent(1), "ΣO = 0 after recovery (boundary {k})");
        assert_eq!(
            fingerprint(recovered.tree(1)),
            control_fps[k],
            "recovered tree diverged from the control run at boundary {k}"
        );
    }
}

/// Migration under scripted load: `ΣO = 0` on both shards throughout,
/// and the migrated session's subsequent `best` equals the unmigrated
/// control run's — across several seeds, deterministically.
#[test]
fn migrate_under_load_meets_the_acceptance_bar_across_seeds() {
    for seed in 1..=6u64 {
        let run = migrate_under_load(seed).unwrap();
        assert_eq!(
            run.migrated_best, run.control_best,
            "seed {seed}: migration changed the recommendation"
        );
        assert!(run.all_quiescent, "seed {seed}: ΣO != 0 after migration");
    }
}

/// The full export bytes round-trip through a real WAL too: an exported
/// session logged as `Open` on the target's WAL recovers there.
#[test]
fn exported_sessions_recover_on_their_new_shard() {
    let dir = temp_dir("export-recover");
    let cfg = StoreConfig::new(&dir);
    let mut source = ScriptedService::new(1, 2, LatencyScript::fixed(1, 4));
    let sp = spec(16, 31);
    source.open(4, &garnet(sp.seed), sp, 1.0);
    source.begin_think(4, 16);
    source.run_to_completion();
    let best = source.best_action(4);
    let bytes = source.export(4).unwrap();
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 4, image: bytes }).unwrap();
    }
    let (recovered, count) =
        DurableScriptedService::recover(1, 2, LatencyScript::fixed(1, 4), &cfg).unwrap();
    assert_eq!(count, 1);
    assert_eq!(recovered.best_action(4), best);
}

// ---------------------------------------------------------------------
// Delta snapshots + group commit (the storage-engine refactor)
// ---------------------------------------------------------------------

/// Fuzz: random mutations of an encoded `DeltaImage` must decode as `Ok`
/// or a typed `Err` — and whatever decodes must `apply` without a panic
/// (typed errors fine) — never a crash, however mangled the bytes.
#[test]
fn fuzzed_delta_mutations_never_panic() {
    let base = searched_image(3, 7);
    let mut cur = base.clone();
    cur.tree.node_mut(Tree::ROOT).n += 1;
    cur.meta.thinks = 2;
    let delta_bytes = DeltaImage::compute(&base.tree, &cur).unwrap().encode();
    let mut rng = Pcg32::new(0xDE17A);
    let mut accepted = 0u32;
    for _ in 0..400 {
        let mut mutated = delta_bytes.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below_usize(mutated.len());
                mutated[i] ^= 1 << rng.below(8);
            }
            1 => {
                mutated.truncate(rng.below_usize(mutated.len()));
            }
            _ => {
                let i = rng.below_usize(mutated.len());
                let n = (rng.below_usize(16) + 1).min(mutated.len() - i);
                for b in &mut mutated[i..i + n] {
                    *b = (rng.below(256)) as u8;
                }
            }
        }
        if let Ok(delta) = DeltaImage::decode(&mutated) {
            accepted += 1;
            // Applying a decoded-but-mutated delta must stay typed too.
            let _ = delta.apply(&base.tree);
        }
    }
    assert!(
        accepted <= 8,
        "checksummed frames should reject nearly all mutations, accepted {accepted}/400"
    );
}

/// The quiet-fleet regression (satellite): a checkpoint pass with no new
/// records since the previous one rewrites zero bytes and purges nothing
/// — idle sessions whose durable state is current are not re-imaged
/// into a fresh segment over and over.
#[test]
fn quiet_fleet_checkpoints_to_zero_bytes_rewritten() {
    let dir = temp_dir("quiet-checkpoint");
    let cfg = StoreConfig { max_segment_bytes: 1, ..StoreConfig::new(&dir) };
    let (mut wal, _) = Wal::open(&cfg).unwrap();
    wal.append(&Record::Open { session: 1, image: image_bytes(1, 3) }).unwrap();
    let out = wal.checkpoint(vec![(1, image_bytes(1, 4))], &[]).unwrap();
    assert!(!out.skipped);
    assert!(out.bytes_rewritten > 0);
    assert_eq!(out.purged, 1);
    // Fleet goes quiet: nothing appended since the compaction — the next
    // pass must write nothing at all.
    let out2 = wal.checkpoint(vec![(1, image_bytes(1, 4))], &[]).unwrap();
    assert!(out2.skipped, "quiet fleet must skip the checkpoint");
    assert_eq!(out2.bytes_rewritten, 0);
    assert_eq!(out2.purged, 0);
    // And the durable state is intact across the skip.
    drop(wal);
    let (_, recovery) = Wal::open(&cfg).unwrap();
    assert_eq!(recovery.sessions.len(), 1);
    assert_eq!(recovery.sessions[0].image.session, 1);
}

/// Crash scripted at every think boundary, in BOTH batch positions:
/// mid-batch (the boundary's snapshot was written but never synced —
/// lost, recovery lands on the previous durable boundary) and
/// post-fsync-pre-ticket (the batch synced but no reply was released —
/// recovery includes the boundary; the store running ahead of acks is
/// the safe direction). `full_every = 3` keeps a live delta chain under
/// most crash points, so mid-delta-chain recovery is exercised
/// throughout. Recovery must reproduce the control run's tree
/// node-for-node every time.
#[test]
fn scripted_crash_at_batch_boundaries_recovers_the_control_tree() {
    const ROUNDS: usize = 4;
    let seed = 13u64;
    let script = LatencyScript::uniform(seed, (1, 3), (2, 7));
    let sp = spec(16, seed);
    let env = garnet(sp.seed); // durable convention: env seed == spec seed

    // Control run: fingerprint at every post-think boundary.
    let mut control = ScriptedService::new(1, 2, script);
    control.open(1, &env, sp.clone(), 1.0);
    let mut fps = Vec::new();
    for _ in 0..ROUNDS {
        control.begin_think(1, 16);
        control.run_to_completion();
        fps.push(fingerprint(control.driver(1).tree()));
    }
    let open_fp = {
        let mut fresh = ScriptedService::new(1, 2, script);
        fresh.open(1, &env, sp.clone(), 1.0);
        fingerprint(fresh.driver(1).tree())
    };

    for k in 0..ROUNDS {
        // Mid-batch: sync after every round except the last.
        let (mut svc, disk) = DurableScriptedService::create_scripted(1, 2, script, 1, 3);
        svc.open(1, &env, sp.clone(), 1.0).unwrap();
        disk.sync(); // the open must commit or the session never existed
        for round in 0..=k {
            svc.begin_think(1, 16);
            svc.run().unwrap();
            if round < k {
                disk.sync();
            }
        }
        svc.crash();
        let (recovered, count) =
            DurableScriptedService::recover_scripted(1, 2, script, &disk, 1, 3).unwrap();
        assert_eq!(count, 1, "mid-batch crash at round {k} lost the session");
        assert!(recovered.quiescent(1), "ΣO = 0 after recovery (round {k})");
        let expect = if k == 0 { &open_fp } else { &fps[k - 1] };
        assert_eq!(
            fingerprint(recovered.tree(1)),
            *expect,
            "mid-batch crash at round {k}: recovery must land on the last durable boundary"
        );

        // Post-fsync-pre-ticket: everything synced, then crash.
        let (mut svc, disk) = DurableScriptedService::create_scripted(1, 2, script, 1, 3);
        svc.open(1, &env, sp.clone(), 1.0).unwrap();
        for _ in 0..=k {
            svc.begin_think(1, 16);
            svc.run().unwrap();
        }
        disk.sync();
        svc.crash();
        let (recovered, count) =
            DurableScriptedService::recover_scripted(1, 2, script, &disk, 1, 3).unwrap();
        assert_eq!(count, 1);
        assert_eq!(
            fingerprint(recovered.tree(1)),
            fps[k],
            "post-fsync crash at round {k}: recovery must include the synced boundary"
        );
    }
}

/// A torn (partially-written) delta record at the tail of the final
/// segment is the expected crash signature: tolerated, truncated away,
/// and recovery lands node-for-node on the last complete boundary.
#[test]
fn torn_delta_tail_is_tolerated_and_recovers_the_boundary() {
    let seed = 29u64;
    let script = LatencyScript::uniform(seed, (1, 3), (2, 7));
    let sp = spec(16, seed);
    let env = garnet(sp.seed);

    let mut control = ScriptedService::new(1, 2, script);
    control.open(1, &env, sp.clone(), 1.0);
    let mut fps = Vec::new();
    for _ in 0..2 {
        control.begin_think(1, 16);
        control.run_to_completion();
        fps.push(fingerprint(control.driver(1).tree()));
    }

    let dir = temp_dir("torn-delta");
    let cfg = StoreConfig { full_every: 4, ..StoreConfig::new(&dir) };
    {
        let mut svc = DurableScriptedService::create(1, 2, script, &cfg).unwrap();
        svc.open(1, &env, sp.clone(), 1.0).unwrap();
        for _ in 0..2 {
            svc.begin_think(1, 16);
            svc.run().unwrap(); // cadence 1 → two delta snapshots on disk
        }
        svc.crash(); // drop drains the commit queue
    }
    // Simulate a crash mid-append of a third (delta) record.
    let seg = dir.join("wal-00000001.log");
    {
        let mut f = fs::OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&[42, 1, 0, 0, 9, 9]).unwrap();
    }
    let (recovered, count) =
        DurableScriptedService::recover(1, 2, script, &cfg).unwrap();
    assert_eq!(count, 1);
    assert!(recovered.quiescent(1));
    assert_eq!(
        fingerprint(recovered.tree(1)),
        fps[1],
        "torn delta tail must truncate away, leaving the last complete boundary"
    );
}

/// A corrupt base image underneath an intact delta chain must refuse
/// recovery with a typed error — applying the chain to a damaged base
/// would resurrect a wrong tree — and never panic.
#[test]
fn corrupt_base_under_an_intact_delta_chain_is_a_typed_error() {
    let base = searched_image(1, 40);
    let mut cur = base.clone();
    cur.tree.node_mut(Tree::ROOT).n += 1;
    cur.meta.thinks = 1;
    let delta = DeltaImage::compute(&base.tree, &cur).unwrap().encode();
    let mut base_bytes = base.encode().unwrap();
    let mid = base_bytes.len() / 2;
    base_bytes[mid] ^= 0x20; // payload damage → the image checksum fails
    let dir = temp_dir("corrupt-base");
    let cfg = StoreConfig::new(&dir);
    {
        let (mut wal, _) = Wal::open(&cfg).unwrap();
        wal.append(&Record::Open { session: 1, image: base_bytes }).unwrap();
        wal.append(&Record::Delta { session: 1, delta }).unwrap();
    }
    match Wal::open(&cfg) {
        Err(
            Error::ChecksumMismatch { .. }
            | Error::Corrupt { .. }
            | Error::Truncated { .. }
            | Error::BadMagic,
        ) => {}
        Err(other) => panic!("expected a decode-class error, got {other}"),
        Ok(_) => panic!("corrupt base must refuse recovery"),
    }
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..2500 {
        if cond() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    panic!("timed out waiting for {what}");
}

/// The group-commit acceptance proof, against the LIVE scheduler: N = 8
/// concurrent sessions on a shard whose scripted store only commits at
/// explicit sync points. Every reply is released only after its ticket's
/// batch is durable (no open completes before the first sync), and one
/// fsync covers all eight records — total fsyncs ≪ total durable
/// records, by counter.
#[test]
fn group_commit_batches_many_sessions_onto_few_fsyncs() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let disk = ScriptedDisk::new();
    let opener_disk = disk.clone();
    let service = SearchService::start_with_store(
        ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        1, // snapshot every think
        move || {
            ScriptedStore::reopen(&opener_disk, 4)
                .map(|(s, r)| (Box::new(s) as Box<dyn SessionStore>, r))
        },
    )
    .unwrap();

    const N: u64 = 8;
    let opened = Arc::new(AtomicUsize::new(0));
    let mut joins = Vec::new();
    for i in 0..N {
        let h = service.handle();
        let opened = Arc::clone(&opened);
        joins.push(std::thread::spawn(move || {
            let sid = h.open(Box::new(garnet(i)), spec(8, i), opts(i)).unwrap();
            opened.fetch_add(1, Ordering::SeqCst);
            // Three thinks: the first per-think snapshot promotes to a
            // full image (a delta against the 1-node open base cannot
            // win), the later ones genuinely delta-encode.
            for _ in 0..3 {
                let t = h.think(sid, 0).unwrap();
                assert!(t.quiescent);
            }
        }));
    }
    // All eight Open records are enqueued, none durable: every reply is
    // held on its ticket.
    wait_until("8 pending open records", || disk.pending_records() == N as usize);
    assert_eq!(
        opened.load(Ordering::SeqCst),
        0,
        "an open reply left before its batch was durable"
    );
    let (_, batches_before, fsyncs_before) = disk.counters();
    assert_eq!((batches_before, fsyncs_before), (0, 0));
    disk.sync(); // ONE fsync admits all eight sessions
    wait_until("all opens acknowledged", || opened.load(Ordering::SeqCst) == N as usize);
    let (records, batches, fsyncs) = disk.counters();
    // (Fast clients may already have enqueued think snapshots — records
    // only grows — but nothing else has synced.)
    assert!(records >= N, "one open record per session");
    assert_eq!((batches, fsyncs), (1, 1), "one batch, one fsync, eight open records");

    // The thinks each log a snapshot; pump sync points until every
    // client got its (held) reply.
    wait_until("think waves drain", || {
        disk.sync();
        joins.iter().all(|j| j.is_finished())
    });
    for j in joins {
        j.join().expect("session thread panicked");
    }
    let (records, _, fsyncs) = disk.counters();
    assert_eq!(records, 4 * N, "8 opens + 24 snapshots");
    assert!(
        fsyncs < records,
        "group commit must beat one-fsync-per-record ({fsyncs} fsyncs / {records} records)"
    );
    let m = service.handle().metrics().unwrap();
    assert_eq!(m.wal_records, 4 * N);
    assert_eq!(m.wal_fsyncs, fsyncs);
    assert!(m.wal_batches >= 1);
    assert!(m.snapshot_bytes_delta > 0, "per-think snapshots delta-encode");
}

/// Kill -9 (process-model: drop without close) during an ACTIVE delta
/// chain: the restarted fleet recovers the session exactly — same
/// recommendation, counters intact — and the metrics show deltas were
/// actually what hit the disk.
#[test]
fn killed_service_recovers_through_a_live_delta_chain() {
    let dir = temp_dir("delta-live-recover");
    let mut cfg = durable_cfg(1, &dir);
    cfg.snapshot_every = 1;
    cfg.full_every = 8;
    let (sid, best_before, bytes_delta) = {
        let svc = ShardedService::start_durable(cfg.clone()).unwrap();
        let h = svc.handle();
        let sid = h.open(Box::new(garnet(6)), spec(24, 6), opts(6)).unwrap();
        for _ in 0..3 {
            let t = h.think(sid, 0).unwrap();
            assert!(t.quiescent);
        }
        let m = h.metrics().unwrap();
        assert!(m.snapshot_bytes_delta > 0, "chain must be live at kill time");
        assert!(m.wal_batches >= 1);
        (sid, h.best_action(sid).unwrap(), m.snapshot_bytes_delta)
        // svc dropped without close: the WAL's view of a SIGKILL.
    };
    assert!(bytes_delta > 0);
    let svc = ShardedService::start_durable(cfg).unwrap();
    let h = svc.handle();
    let m = h.metrics().unwrap();
    assert_eq!(m.sessions_recovered, 1);
    assert_eq!(
        h.best_action(sid).unwrap(),
        best_before,
        "recovery through base + delta chain must reproduce the recommendation"
    );
    let t = h.think(sid, 0).unwrap();
    assert!(t.quiescent);
    let c = h.close(sid).unwrap();
    assert_eq!(c.thinks, 4, "think counter survived the delta-chain recovery");
}

// ---------------------------------------------------------------------
// Flight recorder (crash-surviving journal spill)
// ---------------------------------------------------------------------

/// Tentpole acceptance: under virtual time the flight recorder's
/// spilled segment files are byte-identical across reruns of the same
/// script — timestamps, ordering and framing all deterministic.
#[test]
fn flight_segments_are_byte_identical_across_scripted_reruns() {
    let run = |tag: &str| -> (Vec<u8>, Vec<Event>) {
        let dir = temp_dir(tag);
        let mut svc = ScriptedService::new(1, 2, LatencyScript::uniform(11, (1, 3), (2, 9)));
        svc.attach_flight(&dir).unwrap();
        svc.open(1, &garnet(11), spec(16, 11), 1.0);
        svc.begin_think_traced(1, 16, 0xFACE);
        svc.run_to_completion();
        svc.close(1).unwrap();
        let segments = list_flight_segments(&dir).unwrap();
        assert_eq!(segments.len(), 1, "one boot, small run → one segment");
        let bytes = fs::read(&segments[0].1).unwrap();
        let replay = replay_flight(&dir).unwrap();
        assert!(!replay.torn_tail);
        (bytes, replay.events)
    };
    let (a_bytes, a_events) = run("flight-rerun-a");
    let (b_bytes, b_events) = run("flight-rerun-b");
    assert!(!a_bytes.is_empty());
    assert_eq!(a_bytes, b_bytes, "virtual time ⇒ byte-identical spill");
    assert_eq!(a_events, b_events);
}

/// The spilled stream IS the journal's: replay reconstructs the exact
/// admit → select/issue/done → backprop → think-done timeline the
/// in-memory ring holds, event for event.
#[test]
fn flight_replay_reconstructs_the_scripted_journal_timeline() {
    let dir = temp_dir("flight-replay");
    let mut svc = ScriptedService::new(1, 2, LatencyScript::fixed(1, 4));
    svc.attach_flight(&dir).unwrap();
    svc.open(7, &garnet(5), spec(8, 5), 1.0);
    svc.begin_think(7, 8);
    svc.run_to_completion();
    let journal = svc.trace_events(None, 10_000);
    let replay = replay_flight(&dir).unwrap();
    assert_eq!(replay.events, journal, "flight replay == in-memory journal");
    let kinds: Vec<EventKind> = replay.events.iter().map(|e| e.kind).collect();
    for want in [
        EventKind::SessionOpen,
        EventKind::Admit,
        EventKind::SimIssued,
        EventKind::Backprop,
        EventKind::ThinkDone,
    ] {
        assert!(kinds.contains(&want), "timeline missing {}", want.name());
    }
    assert!(replay.events.windows(2).all(|w| w[0].at_us <= w[1].at_us));
}

/// A segment chopped mid-frame (the on-disk signature of SIGKILL)
/// replays its intact prefix with the tear reported; damage *before*
/// the tail — frames follow it — stays a hard typed error, exactly the
/// WAL's torn-tail ladder.
#[test]
fn flight_torn_tail_recovers_the_intact_prefix() {
    let dir = temp_dir("flight-torn");
    let mut svc = ScriptedService::new(1, 2, LatencyScript::fixed(1, 4));
    svc.attach_flight(&dir).unwrap();
    svc.open(1, &garnet(3), spec(8, 3), 1.0);
    svc.begin_think(1, 8);
    svc.run_to_completion();
    let clean = replay_flight(&dir).unwrap();
    assert!(!clean.torn_tail);
    let n = clean.events.len();
    assert!(n > 4, "need a few frames to tear meaningfully");
    let (_, path) = list_flight_segments(&dir).unwrap().pop().unwrap();
    let len = fs::metadata(&path).unwrap().len();
    fs::OpenOptions::new().write(true).open(&path).unwrap().set_len(len - 7).unwrap();
    let replay = replay_flight(&dir).unwrap();
    assert!(replay.torn_tail, "a chopped final frame is the signature of a kill");
    assert_eq!(replay.events.len(), n - 1, "exactly the torn frame is lost");
    assert_eq!(replay.events[..], clean.events[..n - 1]);
    // Mid-stream damage is never silently skipped, even by the
    // kill-tolerant reader: flip a bit inside the first frame's body.
    let mut data = fs::read(&path).unwrap();
    data[10 + 12 + 2] ^= 0x40;
    fs::write(&path, &data).unwrap();
    match read_flight_segment(&path, true) {
        Err(Error::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|r| r.events.len())),
    }
}

/// Fuzz the segment file like the image/delta codecs: random mutations
/// must come back `Ok` or a typed `Err` — never a panic — and the
/// strict (non-tail) reader rejects essentially everything (truncation
/// landing exactly on a frame boundary, or a downgraded version byte,
/// are the only legal parses).
#[test]
fn fuzzed_flight_segment_mutations_never_panic() {
    let dir = temp_dir("flight-fuzz");
    let mut svc = ScriptedService::new(1, 2, LatencyScript::uniform(13, (1, 3), (2, 9)));
    svc.attach_flight(&dir).unwrap();
    svc.open(1, &garnet(13), spec(16, 13), 1.0);
    svc.begin_think(1, 16);
    svc.run_to_completion();
    let (_, path) = list_flight_segments(&dir).unwrap().pop().unwrap();
    let bytes = fs::read(&path).unwrap();
    let baseline = read_flight_segment(&path, false).unwrap().events.len();
    assert!(baseline > 0);
    let scratch = dir.join("scratch.log");
    let mut rng = Pcg32::new(0xF119);
    let mut accepted = 0u32;
    for _ in 0..400 {
        let mut mutated = bytes.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below_usize(mutated.len());
                mutated[i] ^= 1 << rng.below(8);
            }
            1 => {
                mutated.truncate(rng.below_usize(mutated.len()));
            }
            _ => {
                let i = rng.below_usize(mutated.len());
                let n = (rng.below_usize(16) + 1).min(mutated.len() - i);
                for b in &mut mutated[i..i + n] {
                    *b = (rng.below(256)) as u8;
                }
            }
        }
        fs::write(&scratch, &mutated).unwrap();
        if read_flight_segment(&scratch, false).is_ok() {
            accepted += 1;
        }
        // The kill-tolerant reader must also never panic, and whatever
        // survives is at most the original timeline.
        if let Ok(read) = read_flight_segment(&scratch, true) {
            assert!(read.events.len() <= baseline);
        }
    }
    assert!(
        accepted <= 8,
        "checksummed frames should reject nearly all mutations, accepted {accepted}/400"
    );
}

/// The live deployment spills one recorder per shard under the flight
/// dir. Kill -9 (drop without close), then replay the whole tree and
/// read the admit → durable → reply-sent arc back post-mortem — the
/// exact reconstruction `wu-uct flight` performs in the CI smoke.
#[test]
fn killed_live_service_leaves_a_replayable_flight_dir() {
    let dir = temp_dir("flight-live");
    let flight = dir.join("flight");
    let mut cfg = durable_cfg(2, &dir.join("data"));
    cfg.flight_dir = Some(flight.clone());
    let sid = {
        let svc = ShardedService::start_durable(cfg).unwrap();
        let h = svc.handle();
        let sid = h.open(Box::new(garnet(8)), spec(16, 8), opts(8)).unwrap();
        let t = h.think(sid, 0).unwrap();
        assert!(t.quiescent);
        sid
        // svc dropped without close: the flight dir is all that's left.
    };
    let replay = replay_flight_tree(&flight).unwrap();
    assert_eq!(replay.segments, 2, "one fresh segment per shard per boot");
    let kinds: Vec<EventKind> =
        replay.events.iter().filter(|e| e.session == sid).map(|e| e.kind).collect();
    assert!(kinds.contains(&EventKind::ThinkDone));
    let admit = kinds.iter().position(|&k| k == EventKind::Admit).expect("admit");
    let durable = kinds.iter().rposition(|&k| k == EventKind::Durable).expect("durable");
    let sent = kinds.iter().rposition(|&k| k == EventKind::ReplySent).expect("reply_sent");
    assert!(
        admit < durable && durable < sent,
        "admit → durable → reply_sent arc must survive the kill"
    );
}
