//! Observability regression tests that need a counting global
//! allocator: the metrics scrape path must cost O(buckets), never
//! O(samples).
//!
//! The original `LatencyStats` kept a 65,536-sample reservoir and every
//! `metrics` op cloned **and sorted** it to answer percentiles — a
//! ~0.5 MB allocation plus an O(n log n) sort per scrape, per shard.
//! The log-bucket [`Histogram`] answers the same queries from 37 fixed
//! counters, so a scrape's allocation footprint must stay bounded by
//! the exposition text itself no matter how many samples were recorded.
//! This test records 200k samples and then meters the allocator across
//! a scrape to pin that down.
//!
//! Kept in its own integration-test binary because a `#[global_allocator]`
//! is process-wide: sharing a binary with unrelated tests would make the
//! byte deltas racy (harness threads allocate concurrently).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use wu_uct::obs::{Histogram, NUM_BUCKETS};
use wu_uct::service::ServiceMetrics;

struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);
static CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated_bytes() -> u64 {
    BYTES.load(Ordering::Relaxed)
}

#[test]
fn scrape_allocates_o_buckets_not_o_samples() {
    const SAMPLES: u64 = 200_000;
    let mut m = ServiceMetrics::default();
    for i in 0..SAMPLES {
        // Spread across several decades so most buckets are populated.
        let ms = 0.05 * ((i % 997) + 1) as f64;
        m.think_hist.record(ms);
        m.expand_hist.record(ms * 0.1);
        m.sim_hist.record(ms * 0.5);
        if i % 16 == 0 {
            m.commit_hold_hist.record(ms * 0.25);
        }
    }
    m.thinks = SAMPLES;
    m.derive_latency_scalars();
    assert_eq!(m.think_hist.count(), SAMPLES);

    // Warm-up scrape so one-time buffers (format machinery, string
    // growth) don't count against the steady-state measurement.
    let warm = m.prometheus_text();
    assert!(warm.contains("wuuct_think_latency_ms_bucket"));

    let before = allocated_bytes();
    let text = m.prometheus_text();
    let p99 = m.think_hist.percentile_ms(99.0);
    let spent = allocated_bytes() - before;
    assert!(p99 > 0.0);
    assert!(text.contains("wuuct_think_latency_ms_count"));

    // The old reservoir path cloned + sorted 200k f64s: ≥ 1.6 MB and a
    // sort per scrape. The histogram path's footprint is the rendered
    // text plus O(NUM_BUCKETS) bookkeeping — comfortably under 256 KiB
    // even with string reallocation slack.
    let ceiling = 256 * 1024;
    assert!(
        spent < ceiling,
        "scrape allocated {spent} bytes for {SAMPLES} samples — \
         O(samples) cost is back (ceiling {ceiling}, buckets {NUM_BUCKETS})"
    );
    // And it really is sample-count independent: the same scrape off a
    // 100× smaller recording allocates the same order of magnitude.
    let mut small = ServiceMetrics::default();
    for i in 0..SAMPLES / 100 {
        small.think_hist.record(0.05 * ((i % 997) + 1) as f64);
    }
    small.derive_latency_scalars();
    let _ = small.prometheus_text();
    let before_small = allocated_bytes();
    let _ = small.prometheus_text();
    let spent_small = allocated_bytes() - before_small;
    assert!(
        spent < spent_small.saturating_mul(8).max(ceiling),
        "scrape cost should not scale with samples: {spent} vs {spent_small}"
    );

    // Percentile/mean queries are pure bucket walks — zero allocations.
    // (Same test function as the scrape meter on purpose: the counters
    // are process-global, so a second #[test] running on a sibling
    // harness thread would race the deltas.)
    let mut h = Histogram::new();
    for i in 0..100_000u64 {
        h.record(0.01 * ((i % 3571) + 1) as f64);
    }
    let _ = h.percentile_ms(50.0);
    let before_calls = CALLS.load(Ordering::Relaxed);
    let p50 = h.percentile_ms(50.0);
    let p99_h = h.percentile_ms(99.0);
    let mean = h.mean_ms();
    let calls = CALLS.load(Ordering::Relaxed) - before_calls;
    assert!(p50 > 0.0 && p99_h >= p50 && mean > 0.0);
    assert_eq!(calls, 0, "percentile/mean must be allocation-free bucket walks");
}
