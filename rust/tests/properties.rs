//! Property-based tests over the core invariants, using the in-repo
//! mini-proptest framework (`wu_uct::util::proptest`).

use wu_uct::env::garnet::Garnet;
use wu_uct::env::{atari, Env};
use wu_uct::mcts::common::{backprop, SearchSpec};
use wu_uct::mcts::{Search, SearchSpec as Spec, SequentialUct, WuUct};
use wu_uct::service::{
    SearchService, ServiceConfig, SessionOptions, ShardedConfig, ShardedService,
};
use wu_uct::testkit::{LatencyScript, ScriptedService};
use wu_uct::tree::{select_child, select_child_scalar, ScoreMode, Tree};
use wu_uct::util::proptest::{check, Gen};
use wu_uct::util::stats::{paired_t_test, t_two_sided_p};

/// Random tree built by `gen`: returns (tree, leaf ids).
fn random_tree(g: &mut Gen) -> Tree {
    let mut tree = Tree::new();
    let n_ops = g.usize(1, 40);
    let mut nodes = vec![Tree::ROOT];
    for _ in 0..n_ops {
        let parent = *g.pick(&nodes);
        let action = g.usize(0, 15);
        if tree.node(parent).child_for(action).is_none() {
            let c = tree.add_child(parent, action);
            nodes.push(c);
        }
    }
    tree
}

#[test]
fn prop_backprop_preserves_tree_invariants() {
    check("backprop invariants", 60, |g| {
        let mut tree = random_tree(g);
        let ids: Vec<usize> = tree.iter().map(|(id, _)| id).collect();
        for _ in 0..g.usize(1, 30) {
            let node = *g.pick(&ids);
            backprop(&mut tree, node, g.f64(-5.0, 5.0), g.f64(0.1, 1.0));
        }
        tree.check_invariants();
        true
    });
}

#[test]
fn prop_incomplete_complete_updates_cancel() {
    // Any interleaving of incomplete updates followed by their matching
    // complete updates leaves ΣO = 0 (Eqs. 5–6 are inverses).
    check("O drains to zero", 60, |g| {
        let mut tree = random_tree(g);
        let ids: Vec<usize> = tree.iter().map(|(id, _)| id).collect();
        let mut pending = Vec::new();
        for _ in 0..g.usize(1, 25) {
            let node = *g.pick(&ids);
            tree.for_path_to_root(node, |n| n.o += 1);
            pending.push(node);
        }
        // Complete in a random order.
        while !pending.is_empty() {
            let i = g.usize(0, pending.len() - 1);
            let node = pending.swap_remove(i);
            let mut cur = Some(node);
            let mut ret = g.f64(-1.0, 1.0);
            while let Some(c) = cur {
                let n = tree.node_mut(c);
                assert!(n.o > 0);
                n.o -= 1;
                n.observe(ret);
                ret = n.reward + 0.99 * ret;
                cur = tree.node(c).parent;
            }
        }
        tree.total_unobserved() == 0
    });
}

#[test]
fn prop_selection_only_returns_children() {
    check("selection returns a child", 80, |g| {
        let mut tree = random_tree(g);
        let ids: Vec<usize> = tree.iter().map(|(id, _)| id).collect();
        for &id in &ids {
            let n = tree.node_mut(id);
            n.n = g.u32(0, 100);
            n.o = g.u32(0, 8);
            n.v = g.f64(-2.0, 2.0);
        }
        for &id in &ids {
            let mode = *g.pick(&[ScoreMode::Uct, ScoreMode::WuUct, ScoreMode::VirtualLoss]);
            match select_child(&tree, id, mode, g.f64(0.0, 3.0)) {
                Some(child) => {
                    if !tree.node(id).children.iter().any(|&(_, c)| c == child) {
                        return false;
                    }
                }
                None => {
                    if !tree.node(id).children.is_empty() {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_soa_selection_matches_scalar() {
    // The SoA lane scan behind `select_child` must return the *identical*
    // argmax to the one-node-at-a-time scalar reference — same +inf
    // first-visit priority, same lowest-index tie-break — for every mode,
    // on trees mutated through every invalidation route (stat writes,
    // backup walks, expansion) between selections.
    check("SoA argmax == scalar argmax", 80, |g| {
        let mut tree = random_tree(g);
        for round in 0..3 {
            let ids: Vec<usize> = tree.iter().map(|(id, _)| id).collect();
            for &id in &ids {
                let n = tree.node_mut(id);
                n.n = g.u32(0, 50);
                n.o = g.u32(0, 8);
                n.v = g.f64(-2.0, 2.0);
                n.vloss = if g.usize(0, 3) == 0 { g.f64(0.0, 4.0) } else { 0.0 };
                n.vcount = g.u32(0, 3);
            }
            // Exercise the other dirtying routes between rounds too.
            tree.for_path_to_root(*g.pick(&ids), |n| n.o += 1);
            if round > 0 {
                let parent = *g.pick(&ids);
                let action = g.usize(16, 31); // disjoint from random_tree's
                if tree.node(parent).child_for(action).is_none() {
                    tree.add_child(parent, action);
                }
            }
            let beta = g.f64(0.0, 3.0);
            for &id in &ids {
                for mode in [ScoreMode::Uct, ScoreMode::WuUct, ScoreMode::VirtualLoss] {
                    let soa = select_child(&tree, id, mode, beta);
                    let scalar = select_child_scalar(&tree, id, mode, beta);
                    if soa != scalar {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_env_snapshot_restore_is_identity() {
    // For every suite game: snapshot → random walk → restore → identical
    // replay against an untouched clone.
    check("snapshot/restore identity", 30, |g| {
        let name = *g.pick(&atari::GAMES);
        let mut env = atari::make(name, g.u64());
        // Random warmup walk.
        for _ in 0..g.usize(0, 10) {
            if env.is_terminal() {
                break;
            }
            let acts = env.legal_actions();
            let a = *g.pick(&acts);
            env.step(a);
        }
        if env.is_terminal() {
            return true;
        }
        let snap = env.snapshot();
        let mut copy = atari::make(name, 0);
        copy.restore(&snap);
        for _ in 0..g.usize(1, 15) {
            if env.is_terminal() {
                break;
            }
            let acts = env.legal_actions();
            let a = *g.pick(&acts);
            if env.step(a) != copy.step(a) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_env_determinism_same_seed_same_trajectory() {
    check("env determinism", 30, |g| {
        let name = *g.pick(&atari::GAMES);
        let seed = g.u64();
        let script: Vec<u64> = (0..20).map(|_| g.u64()).collect();
        let run = |script: &[u64]| {
            let mut env = atari::make(name, seed);
            let mut total = 0.0;
            for &s in script {
                if env.is_terminal() {
                    break;
                }
                let acts = env.legal_actions();
                let a = acts[(s % acts.len() as u64) as usize];
                total += env.step(a).reward;
            }
            total
        };
        run(&script) == run(&script)
    });
}

#[test]
fn prop_search_returns_legal_action() {
    check("search yields legal action", 15, |g| {
        let env = Garnet::new(g.usize(4, 20), g.usize(2, 6), g.u32(3, 20), 0.0, g.u64());
        let spec = Spec {
            max_simulations: g.u32(4, 24),
            rollout_limit: g.u32(1, 10),
            max_depth: g.u32(1, 10),
            seed: g.u64(),
            ..Spec::default()
        };
        let action = if g.bool(0.5) {
            SequentialUct::new(spec).search(&env).best_action
        } else {
            WuUct::new(spec, 1, g.usize(1, 4)).search(&env).best_action
        };
        env.legal_actions().contains(&action)
    });
}

#[test]
fn prop_t_test_properties() {
    check("t-test sanity", 100, |g| {
        let n = g.usize(3, 20);
        let a: Vec<f64> = (0..n).map(|_| g.f64(-10.0, 10.0)).collect();
        let b: Vec<f64> = (0..n).map(|_| g.f64(-10.0, 10.0)).collect();
        let ab = paired_t_test(&a, &b);
        let ba = paired_t_test(&b, &a);
        // Antisymmetric t, symmetric p.
        let ok_sym = (ab.t + ba.t).abs() < 1e-9 && (ab.p - ba.p).abs() < 1e-9;
        let ok_range = (0.0..=1.0).contains(&ab.p);
        // Identical samples: never significant.
        let aa = paired_t_test(&a, &a);
        ok_sym && ok_range && aa.p == 1.0
    });
}

#[test]
fn prop_t_distribution_p_monotone_in_t() {
    check("p decreases in |t|", 100, |g| {
        let df = g.f64(1.0, 50.0);
        let t1 = g.f64(0.0, 5.0);
        let t2 = t1 + g.f64(0.01, 5.0);
        t_two_sided_p(t2, df) <= t_two_sided_p(t1, df) + 1e-12
    });
}

#[test]
fn prop_advance_root_preserves_subtree_statistics() {
    // Re-rooting at a child keeps the retained subtree's {N, V, O},
    // rewards and shape bit-for-bit, rebased to depth 0.
    check("advance_root preserves stats", 60, |g| {
        let mut tree = random_tree(g);
        // Give the tree coherent statistics via real backprops, plus some
        // in-flight O marks that advance_root must carry over verbatim.
        let ids: Vec<usize> = tree.iter().map(|(id, _)| id).collect();
        for _ in 0..g.usize(1, 25) {
            let node = *g.pick(&ids);
            backprop(&mut tree, node, g.f64(-2.0, 2.0), g.f64(0.5, 1.0));
        }
        for &id in &ids {
            tree.node_mut(id).reward = g.f64(-1.0, 1.0);
            tree.node_mut(id).o = g.u32(0, 2);
        }
        let root_children = tree.node(Tree::ROOT).children.clone();
        if root_children.is_empty() {
            return true; // nothing to advance into
        }
        let &(action, child) = g.pick(&root_children);
        // Expected: the child subtree, collected before the move.
        let mut expect_n = 0u64;
        let mut expect_o = 0u64;
        let mut count = 0usize;
        let mut stack = vec![child];
        while let Some(id) = stack.pop() {
            let n = tree.node(id);
            expect_n += n.n as u64;
            expect_o += n.o as u64;
            count += 1;
            stack.extend(n.children.iter().map(|&(_, c)| c));
        }
        let (child_n, child_v, child_o) =
            (tree.node(child).n, tree.node(child).v, tree.node(child).o);
        let retained = tree.advance_root(action);
        if retained != Some(count) {
            return false;
        }
        let root = tree.node(Tree::ROOT);
        if root.parent.is_some() || root.depth != 0 {
            return false;
        }
        if root.n != child_n || root.v != child_v || root.o != child_o {
            return false;
        }
        let total_n: u64 = tree.iter().map(|(_, n)| n.n as u64).sum();
        let total_o: u64 = tree.iter().map(|(_, n)| n.o as u64).sum();
        if total_n != expect_n || total_o != expect_o || tree.len() != count {
            return false;
        }
        tree.check_invariants();
        true
    });
}

#[test]
fn prop_interleaved_sessions_quiesce_over_shared_pools() {
    // The paper's ΣO = 0 invariant, per session, when several sessions'
    // rollouts interleave arbitrarily over one shared worker fleet.
    check("per-session O drains over shared pools", 5, |g| {
        let service = SearchService::start(ServiceConfig {
            expansion_workers: g.usize(1, 2),
            simulation_workers: g.usize(2, 4),
            ..ServiceConfig::default()
        });
        let n_sessions = g.usize(2, 5);
        let seeds: Vec<u64> = (0..n_sessions).map(|_| g.u64()).collect();
        let budgets: Vec<u32> = (0..n_sessions).map(|_| g.u32(4, 40)).collect();
        let ok = std::thread::scope(|scope| {
            let joins: Vec<_> = seeds
                .iter()
                .zip(&budgets)
                .map(|(&seed, &budget)| {
                    let h = service.handle();
                    scope.spawn(move || {
                        let env = Box::new(Garnet::new(12, 3, 20, 0.0, seed));
                        let spec = Spec {
                            max_simulations: budget,
                            rollout_limit: 6,
                            max_depth: 8,
                            seed,
                            ..Spec::default()
                        };
                        let sid = h.open(env, spec, SessionOptions::default()).unwrap();
                        let mut quiescent = true;
                        for _ in 0..3 {
                            let t = h.think(sid, budget).unwrap();
                            quiescent &= t.quiescent && t.sims == budget;
                            let adv = h.advance(sid, t.action).unwrap();
                            if adv.done {
                                break;
                            }
                        }
                        let close = h.close(sid).unwrap();
                        quiescent && close.unobserved == 0
                    })
                })
                .collect();
            joins.into_iter().all(|j| j.join().expect("session thread panicked"))
        });
        ok
    });
}

#[test]
fn prop_virtual_deadline_fairness_bounds_lag_at_every_tick() {
    // With K equal-weight sessions over one shard, no session's
    // completed-simulation count may lag the per-session mean by more
    // than the shard's total in-flight capacity (+ one stride of
    // scheduling slack) at any tick. Why that bound: stride scheduling
    // keeps *issued* counts of always-eligible equal-weight lanes within
    // 1 of each other, and the dispatch gate bounds total in-flight
    // rollouts by exp_cap + sim_cap, so
    //   mean(completed) − completed_i ≤ 1 + inflight_i ≤ 1 + exp + sim.
    // The testkit replays the live FairQueue + gate deterministically, so
    // a violation would reproduce from the printed seed.
    check("fairness lag bound", 12, |g| {
        let k = g.usize(2, 6);
        let exp_cap = g.usize(1, 2);
        let sim_cap = g.usize(1, 4);
        let budget = g.u32(20, 50);
        let script = LatencyScript::uniform(g.u64(), (1, 4), (1, 9));
        let mut svc = ScriptedService::new(exp_cap, sim_cap, script);
        for i in 1..=k as u64 {
            let env = Garnet::new(12, 3, 40, 0.0, g.u64());
            let spec = Spec {
                max_simulations: budget,
                rollout_limit: 6,
                max_depth: 10,
                seed: g.u64(),
                ..Spec::default()
            };
            svc.open(i, &env, spec, 1.0);
            svc.begin_think(i, budget);
        }
        let bound = (exp_cap + sim_cap + 2) as f64;
        let mut fair = true;
        svc.run(|_, counts| {
            let total: u64 = counts.values().map(|&c| c as u64).sum();
            let mean = total as f64 / counts.len() as f64;
            for &c in counts.values() {
                if mean - c as f64 > bound {
                    fair = false;
                }
            }
        });
        fair && (1..=k as u64).all(|i| {
            svc.quiescent(i) && svc.completed()[&i] == budget
        })
    });
}

#[test]
fn prop_sharded_sessions_quiesce_with_stealing() {
    // The per-session ΣO = 0 invariant must survive sharding: sessions
    // hash to different schedulers, and overflowed simulations may be
    // executed by a *different* shard's pool than the tree's owner.
    check("sharded ΣO drains", 3, |g| {
        let svc = ShardedService::start(ShardedConfig {
            shards: g.usize(2, 3),
            shard: ServiceConfig {
                expansion_workers: g.usize(1, 2),
                simulation_workers: g.usize(1, 2),
                ..ServiceConfig::default()
            },
            ..ShardedConfig::default()
        });
        let n_sessions = g.usize(2, 5);
        let seeds: Vec<u64> = (0..n_sessions).map(|_| g.u64()).collect();
        let budgets: Vec<u32> = (0..n_sessions).map(|_| g.u32(8, 48)).collect();
        let handle = svc.handle();
        std::thread::scope(|scope| {
            let joins: Vec<_> = seeds
                .iter()
                .zip(&budgets)
                .map(|(&seed, &budget)| {
                    let h = handle.clone();
                    scope.spawn(move || {
                        let env = Box::new(Garnet::new(12, 3, 20, 0.0, seed));
                        let spec = Spec {
                            max_simulations: budget,
                            rollout_limit: 6,
                            max_depth: 8,
                            seed,
                            ..Spec::default()
                        };
                        let sid = h.open(env, spec, SessionOptions::default()).unwrap();
                        let mut ok = true;
                        for _ in 0..2 {
                            let t = h.think(sid, budget).unwrap();
                            ok &= t.quiescent && t.sims == budget;
                            let adv = h.advance(sid, t.action).unwrap();
                            if adv.done {
                                break;
                            }
                        }
                        let close = h.close(sid).unwrap();
                        ok && close.unobserved == 0
                    })
                })
                .collect();
            joins.into_iter().all(|j| j.join().expect("session thread panicked"))
        })
    });
}

#[test]
fn prop_wu_uct_budget_always_exact() {
    check("WU-UCT completes exactly T_max", 10, |g| {
        let env = Garnet::new(12, 3, 15, g.f64(0.0, 0.3), g.u64());
        let t_max = g.u32(4, 40);
        let spec = SearchSpec {
            max_simulations: t_max,
            rollout_limit: 5,
            seed: g.u64(),
            ..SearchSpec::default()
        };
        let mut s = WuUct::new(spec, g.usize(1, 3), g.usize(1, 6));
        s.search(&env).simulations == t_max
    });
}

#[test]
fn prop_ring_placement_skew_stays_under_twice_the_mean() {
    // Consistent-hash placement quality (the store's rebalancer treats
    // the ring as "good enough that only live load needs moving"): over
    // 10k random keys, no shard's share may reach 2x the mean.
    check("ring skew < 2x mean", 10, |g| {
        let shards = g.usize(2, 8);
        let ring = wu_uct::service::HashRing::new(shards, 64).unwrap();
        let mut counts = vec![0usize; shards];
        for _ in 0..10_000 {
            counts[ring.place(g.u64())] += 1;
        }
        let mean = 10_000.0 / shards as f64;
        counts.iter().all(|&c| (c as f64) < 2.0 * mean)
    });
}

#[test]
fn prop_session_images_roundtrip_for_random_searched_trees() {
    // The store codec is lossless on any tree WU-UCT can actually
    // produce: random Garnet searches under random scripted latencies
    // encode, decode and re-encode bit-identically, and the revived
    // driver reproduces the recommendation.
    use wu_uct::store::codec::{SessionImage, SessionMeta};
    use wu_uct::testkit::{scripted_driver, LatencyScript};
    check("session image roundtrip", 12, |g| {
        let seed = g.u64() % 100_000;
        let env = Garnet::new(15, 3, 30, 0.0, seed);
        let spec = SearchSpec {
            max_simulations: g.u32(4, 48),
            rollout_limit: 6,
            max_depth: 10,
            seed,
            ..SearchSpec::default()
        };
        let script = LatencyScript::uniform(g.u64(), (1, 3), (1, 8));
        let driver = scripted_driver(spec, &env, g.usize(1, 2), g.usize(1, 4), script);
        let meta = SessionMeta { env_seed: seed, ..SessionMeta::default() };
        let image = SessionImage::capture(7, &driver, meta).unwrap();
        let bytes = image.encode().unwrap();
        let back = SessionImage::decode(&bytes).unwrap();
        let stable = back.encode().unwrap() == bytes;
        let revived = back.into_driver(wu_uct::service::proto::make_env).unwrap();
        stable
            && revived.tree().len() == driver.tree().len()
            && revived.best_action() == driver.best_action()
            && revived.tree().total_unobserved() == 0
    });
}

#[test]
fn prop_inspect_summary_tracks_tree_unobserved_at_every_tick() {
    // The introspection tentpole's consistency claim: the inspect
    // summary's ΣO equals `Tree::total_unobserved` at EVERY scheduler
    // tick mid-think — watching the unobserved is only useful if the
    // watcher agrees with the tree — and drains to exactly 0 at
    // quiescence. Scripted service, so any violation replays from the
    // printed seed.
    use std::cell::Cell;
    let saw_inflight = Cell::new(false);
    check("inspect ΣO == tree ΣO at every tick", 8, |g| {
        let k = g.usize(1, 4);
        let budget = g.u32(8, 32);
        let script = LatencyScript::uniform(g.u64(), (1, 4), (1, 9));
        let mut svc = ScriptedService::new(g.usize(1, 2), g.usize(2, 4), script);
        for i in 1..=k as u64 {
            let env = Garnet::new(12, 3, 25, 0.0, g.u64());
            let spec = Spec {
                max_simulations: budget,
                rollout_limit: 6,
                max_depth: 10,
                seed: g.u64(),
                ..Spec::default()
            };
            svc.open(i, &env, spec, 1.0);
            svc.begin_think(i, budget);
        }
        let mut consistent = true;
        svc.run_inspecting(|_, svc| {
            for i in 1..=k as u64 {
                let s = svc.summary(i, 3);
                let tree = svc.driver(i).tree();
                consistent &= s.unobserved == tree.total_unobserved();
                consistent &= s.tree_size == tree.len() as u64;
                if s.unobserved > 0 {
                    saw_inflight.set(true);
                }
                // Finite scores decompose as exploitation + exploration.
                for a in &s.top {
                    if a.score.is_finite() {
                        consistent &= (a.q + a.explore - a.score).abs() < 1e-9;
                    }
                }
            }
        });
        let quiesced = (1..=k as u64).all(|i| {
            let s = svc.summary(i, 3);
            s.unobserved == 0 && !s.thinking && svc.driver(i).tree().total_unobserved() == 0
        });
        consistent && quiesced
    });
    assert!(
        saw_inflight.get(),
        "the property never observed a mid-think tick with ΣO > 0 — it proved nothing"
    );
}
