//! Integration tests: algorithms × environments × runtime, exercising the
//! paper's qualitative claims at miniature scale.

use std::time::Duration;

use wu_uct::env::tapgame::{Level, TapGame};
use wu_uct::env::{atari, Env, SlowEnv};
use wu_uct::gameplay::{mean_reward, play_episodes};
use wu_uct::mcts::{by_name, LeafP, Search, SearchSpec, SequentialUct, TreeP, WuUct, ALGORITHMS};
use wu_uct::util::timer::Phase;

fn mini_spec(seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: 24,
        rollout_limit: 12,
        seed,
        ..SearchSpec::default()
    }
}

#[test]
fn all_algorithms_play_all_games_sane() {
    // Smoke matrix: every algorithm completes an episode prefix on every
    // game without panicking and returns finite reward.
    for game in ["Alien", "Breakout", "Freeway", "Boxing", "RoadRunner"] {
        for algo in ALGORITHMS {
            let mut s = by_name(algo, mini_spec(1), 2).expect("known algorithm");
            let mut env = atari::make(game, 1);
            let rs = play_episodes(s.as_mut(), env.as_mut(), 3, 1, 8);
            assert!(
                rs[0].total_reward.is_finite(),
                "{algo} on {game} returned non-finite reward"
            );
            assert!(rs[0].steps > 0);
        }
    }
}

#[test]
fn wu_uct_profile_matches_fig2_shape() {
    // Worker simulation time must dominate master bookkeeping, and the
    // master's communication must be a small fraction — Fig. 2's story —
    // on the latency-simulated emulator.
    let inner = TapGame::new(Level::level35(), 5);
    let env = SlowEnv::new(Box::new(inner), Duration::from_micros(120));
    let mut s = WuUct::new(
        SearchSpec {
            max_simulations: 32,
            rollout_limit: 8,
            seed: 2,
            ..SearchSpec::tap_game()
        },
        2,
        4,
    );
    let r = s.search(&env);
    let sim = r.workers.total(Phase::Simulation);
    let master_work = r.master.total(Phase::Selection) + r.master.total(Phase::Backpropagation);
    assert!(
        sim > master_work,
        "simulation {sim:?} should dominate master work {master_work:?}"
    );
    let comm = r.master.total(Phase::Communication);
    assert!(comm < sim, "communication {comm:?} must be below simulation {sim:?}");
}

#[test]
fn wu_uct_tree_is_diverse_under_parallelism() {
    // The collapse-of-exploration story: with 8 parallel workers, LeafP
    // concentrates its budget on ~budget/8 leaves while WU-UCT keeps
    // expanding — its tree must be substantially larger.
    let env = atari::make("MsPacman", 3);
    let spec = SearchSpec {
        max_simulations: 48,
        rollout_limit: 10,
        seed: 4,
        ..SearchSpec::default()
    };
    let mut wu = WuUct::new(spec.clone(), 1, 8);
    let wu_tree = wu.search(env.as_ref()).tree_size;
    let mut leafp = LeafP::new(spec, 8);
    let leafp_tree = leafp.search(env.as_ref()).tree_size;
    assert!(
        wu_tree > leafp_tree,
        "WU-UCT tree {wu_tree} should out-grow LeafP tree {leafp_tree}"
    );
}

#[test]
fn treep_large_virtual_loss_hurts_exploitation() {
    // Section 4 / Table 5: an over-large r_VL diverts workers off the best
    // arm. Compare tree concentration on the best root child.
    let env = atari::make("Boxing", 2);
    let spec = SearchSpec {
        max_simulations: 60,
        rollout_limit: 10,
        seed: 5,
        ..SearchSpec::default()
    };
    let run = |r_vl: f64| {
        let mut s = TreeP::new(spec.clone(), 4, r_vl);
        s.search(env.as_ref()).root_value
    };
    let mild = run(0.5);
    let harsh = run(50.0);
    // With a huge virtual loss the selection thrashes; root value estimate
    // is built from more diluted arms. We only require both to run and the
    // estimates to differ — the reward-level effect is Table 5's bench.
    assert!(mild.is_finite() && harsh.is_finite());
}

#[test]
fn sequential_uct_is_the_quality_ceiling_at_scale() {
    // UCT with the same budget should do at least as well as the fast
    // parallel variants on average (the paper's framing).
    let games = ["Breakout", "Boxing"];
    let mut uct_total = 0.0;
    let mut wu_total = 0.0;
    for game in games {
        let mut uct = SequentialUct::new(mini_spec(7));
        let mut env = atari::make(game, 1);
        uct_total += mean_reward(&play_episodes(&mut uct, env.as_mut(), 9, 2, 12));
        let mut wu = WuUct::new(mini_spec(7), 1, 8);
        let mut env = atari::make(game, 1);
        wu_total += mean_reward(&play_episodes(&mut wu, env.as_mut(), 9, 2, 12));
    }
    // WU-UCT shouldn't catastrophically trail UCT (its whole point).
    assert!(
        wu_total > uct_total - 150.0,
        "WU-UCT {wu_total} vs UCT {uct_total}"
    );
}

#[test]
fn network_policy_search_end_to_end() {
    // Full three-layer stack: WU-UCT + eval server + AOT network.
    let dir = wu_uct::runtime::artifacts_dir();
    if !dir.join("meta.txt").exists() {
        eprintln!("artifacts missing — skipping e2e network test");
        return;
    }
    let server = wu_uct::runtime::EvalServer::start(&dir, Duration::from_micros(100)).unwrap();
    let factory = wu_uct::runtime::NetworkPolicy::factory(server.handle());
    let mut s = WuUct::with_policy(
        SearchSpec {
            max_simulations: 12,
            rollout_limit: 6,
            seed: 3,
            ..SearchSpec::default()
        },
        1,
        4,
        factory,
    );
    let env = atari::make("Breakout", 2);
    let r = s.search(env.as_ref());
    assert_eq!(r.simulations, 12);
    assert!(env.legal_actions().contains(&r.best_action));
    assert!(server.stats().requests > 0, "network must have been queried");
}

#[test]
fn tap_game_full_episode_with_every_algorithm() {
    for algo in ALGORITHMS {
        let mut s = by_name(
            algo,
            SearchSpec {
                max_simulations: 20,
                rollout_limit: 8,
                seed: 6,
                ..SearchSpec::tap_game()
            },
            2,
        )
        .expect("known algorithm");
        let mut game = TapGame::new(Level::level35(), 8);
        while !game.is_terminal() {
            let r = s.search(&game);
            let legal = game.legal_actions();
            let a = if legal.contains(&r.best_action) { r.best_action } else { legal[0] };
            game.step(a);
        }
        assert!(game.steps_used() <= Level::level35().steps);
    }
}

#[test]
fn speedup_holds_end_to_end_on_slow_emulator() {
    let _serial = wu_uct::util::timer::TIMING_TEST_LOCK.lock().unwrap();
    let inner = TapGame::new(Level::level35(), 5);
    let env = SlowEnv::new(Box::new(inner), Duration::from_micros(250));
    let spec = SearchSpec {
        max_simulations: 24,
        rollout_limit: 8,
        seed: 2,
        ..SearchSpec::tap_game()
    };
    let mut t = vec![];
    for n_sim in [1usize, 8] {
        let mut s = WuUct::new(spec.clone(), 1, n_sim);
        s.search(&env); // warmup
        let start = std::time::Instant::now();
        s.search(&env);
        t.push(start.elapsed());
    }
    assert!(
        t[1] * 2 < t[0] * 3,
        "8 workers {:?} should be well under 1 worker {:?}",
        t[1],
        t[0]
    );
}
