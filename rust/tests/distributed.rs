//! Cross-process shard hosts: the distributed invariants.
//!
//! Three layers of proof, mirroring DESIGN.md §9's failure matrix:
//!
//! 1. **Deterministic** (`FakeHostNet`): a session opened on host A,
//!    thought on, live-migrated to host B over the *same*
//!    `migrate_over` handshake the live router runs, and thought on
//!    again produces the same best-action sequence as the in-process
//!    (single-process) control — with `ΣO = 0` on both hosts — and a
//!    link severed at every handshake step either completes or cleanly
//!    aborts with the source unsealed, never losing the session. Same
//!    seed ⇒ identical event log (golden).
//! 2. **Wire layer**: the four host ops round-trip over the real
//!    dispatcher, reject unknown fields, and malformed / oversized /
//!    truncated image frames are typed error replies.
//! 3. **Live TCP**: a router over two in-process shard-host services
//!    proxies the full lifecycle, migrates across hosts, survives a
//!    killed host (typed `HostUnreachable`, counted in metrics), and a
//!    restarted router re-learns placement from health probes.
//! 4. **Chaos** (`testkit::chaos`): the seeded scheduler sweeps whole
//!    control-plane deployments — 200+ seeds, every op followed by the
//!    global invariant check — plus a regression corpus of shrunk
//!    schedules replayed against deliberately reverted guards, and a
//!    crash-at-every-step matrix around lease handover and standby
//!    promotion.
//! 5. **Control plane over TCP**: two hot-hot routers sharing one lease
//!    table (the loser of a placement race sees the typed `LeaseLost`),
//!    and a replicated primary whose abrupt death promotes the standby
//!    with every session intact, node for node.

use wu_uct::env::garnet::Garnet;
use wu_uct::mcts::SearchSpec;
use wu_uct::service::json::Json;
use wu_uct::service::proto::{handle_line, image_from_hex};
use wu_uct::service::scheduler::{ServiceConfig, SessionOptions};
use wu_uct::service::shard::{ShardedConfig, ShardedService};
use wu_uct::service::{
    Busy, HostUnreachable, LeaseLost, LeaseTable, Router, RouterConfig, SessionApi, TcpServer,
};
use wu_uct::store::migrate::{migrate_over, HandshakeOutcome, MigrationLink, Recovering};
use wu_uct::testkit::{
    chaos_schedule, replay_chaos, run_chaos, shrink_chaos, ChaosOp, FakeHost, FakeHostNet, Guards,
    LatencyScript, ScriptEvent, ScriptedService,
};

fn spec(sims: u32, seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: sims,
        rollout_limit: 8,
        max_depth: 12,
        seed,
        ..SearchSpec::default()
    }
}

/// The durable convention: envs are rebuilt as `make_env("garnet",
/// seed)`, so construct them with the spec's seed and garnet's wire
/// parameters.
fn env(seed: u64) -> Garnet {
    Garnet::new(15, 3, 30, 0.0, seed)
}

fn opts(seed: u64) -> SessionOptions {
    SessionOptions { env_seed: seed, ..SessionOptions::default() }
}

// ---------------------------------------------------------------------
// 1. Deterministic cross-host invariants (FakeHostNet)
// ---------------------------------------------------------------------

fn source_script() -> LatencyScript {
    LatencyScript::uniform(11, (1, 3), (2, 9))
}

fn target_script() -> LatencyScript {
    LatencyScript::uniform(12, (1, 3), (2, 9))
}

/// The acceptance claim: open on host A, think via the router, migrate
/// to host B over the wire handshake, think again — the best-action
/// sequence must equal a single-process control that moved the session
/// with the in-process export/import path, and `ΣO = 0` must hold for
/// every session on both hosts.
#[test]
fn migrated_session_matches_the_single_process_control() {
    let sid = 1u64;
    // Control: one process, the in-process migration path.
    let mut ctl_a = ScriptedService::new(2, 4, source_script());
    let mut ctl_b = ScriptedService::new(2, 4, target_script());
    ctl_a.open(sid, &env(101), spec(24, 101), 1.0);
    ctl_a.open(2, &env(102), spec(24, 102), 1.0);
    ctl_b.open(11, &env(111), spec(24, 111), 1.0);
    ctl_a.begin_think(sid, 24);
    ctl_a.begin_think(2, 24);
    ctl_a.run_to_completion();
    ctl_b.begin_think(11, 24);
    ctl_b.run_to_completion();
    let control_best1 = ctl_a.best_action(sid);
    let image = ctl_a.export(sid).unwrap();
    ctl_b.import(&image).unwrap();
    ctl_b.begin_think(sid, 24);
    ctl_b.begin_think(11, 24);
    ctl_b.run_to_completion();
    let control_best2 = ctl_b.best_action(sid);

    // Distributed: identical hosts and schedules, hand-off over the
    // fake wire via the router's handshake code path.
    let mut host_a = FakeHost::new(2, 4, source_script());
    let mut host_b = FakeHost::new(2, 4, target_script());
    host_a.open(sid, &env(101), spec(24, 101), 1.0).unwrap();
    host_a.open(2, &env(102), spec(24, 102), 1.0).unwrap();
    host_b.open(11, &env(111), spec(24, 111), 1.0).unwrap();
    host_a.begin_think(sid, 24).unwrap();
    host_a.begin_think(2, 24).unwrap();
    host_a.run_to_completion();
    host_b.begin_think(11, 24).unwrap();
    host_b.run_to_completion();
    let mut net = FakeHostNet::new(vec![host_a, host_b]);
    assert_eq!(
        net.host(0).best_action(sid).unwrap(),
        control_best1,
        "pre-migration recommendation must match the control"
    );
    let out = migrate_over(&mut net, sid, 0, 1);
    assert!(matches!(out, HandshakeOutcome::Moved), "{out:?}");
    {
        let b = net.host_mut(1);
        b.begin_think(sid, 24).unwrap();
        b.begin_think(11, 24).unwrap();
        b.run_to_completion();
    }
    assert_eq!(
        net.host(1).best_action(sid).unwrap(),
        control_best2,
        "post-migration think must match the unmigrated-control sequence"
    );
    // The paper's invariant on both sides of the wire.
    assert!(net.host(0).quiescent(2), "ΣO = 0 on the source host");
    assert!(net.host(1).quiescent(sid), "ΣO = 0 for the migrated session");
    assert!(net.host(1).quiescent(11), "ΣO = 0 for the target's own load");
    assert!(!net.host(0).contains(sid), "the source forgot its copy");
}

fn handshake_net() -> FakeHostNet {
    let mut a = FakeHost::new(2, 4, LatencyScript::uniform(21, (1, 3), (2, 9)));
    a.open(1, &env(201), spec(16, 201), 1.0).unwrap();
    a.begin_think(1, 16).unwrap();
    a.run_to_completion();
    let b = FakeHost::new(2, 4, LatencyScript::uniform(22, (1, 3), (2, 9)));
    FakeHostNet::new(vec![a, b])
}

fn serves(host: &mut FakeHost, sid: u64) {
    host.begin_think(sid, 8).unwrap();
    host.run_to_completion();
    assert!(host.quiescent(sid), "ΣO must drain for session {sid}");
}

/// The fault matrix: a link severed (or a reply lost) at each of the
/// three handshake steps either completes the move or cleanly aborts
/// with the source unsealed — the session is never lost, at worst
/// briefly duplicated or sealed-awaiting-repair.
#[test]
fn a_link_severed_at_every_handshake_step_never_loses_the_session() {
    // (handshake rpc number, host whose link faults, reply-lost?)
    let cases = [
        (1u64, 0usize, false), // export request never arrives
        (1, 0, true),          // export lands (seals!), reply lost
        (2, 1, false),         // install request never arrives
        (2, 1, true),          // install lands (duplicate!), reply lost
        (3, 0, false),         // forget request never arrives
        (3, 0, true),          // forget lands, reply lost
    ];
    for &(step, fault_host, reply_lost) in &cases {
        let label = format!("step={step} host={fault_host} reply_lost={reply_lost}");
        let mut net = handshake_net();
        if reply_lost {
            net.drop_reply_at(step);
        } else {
            net.script_at(step, ScriptEvent::Sever(fault_host));
        }
        let out = migrate_over(&mut net, 1, 0, 1);
        match out {
            HandshakeOutcome::Moved => panic!("{label}: fault was never exercised"),
            HandshakeOutcome::Aborted(_) => {
                // Steps 1 (reply lost) and 2: the source unsealed and
                // serves again as if nothing happened.
                assert!(!net.host(0).is_sealed(1), "{label}");
                serves(net.host_mut(0), 1);
                if step == 2 && reply_lost {
                    // The install landed: duplicated, never lost. The
                    // source stays authoritative (no override written).
                    assert!(net.host(1).contains(1), "{label}");
                    assert!(net.host(1).quiescent(1), "{label}");
                } else {
                    assert!(!net.host(1).contains(1), "{label}");
                }
            }
            HandshakeOutcome::AbortedSealed(_, pending) => {
                // Step 1 severed: the abort could not be delivered
                // either. Heal and repair; the source serves again.
                assert_eq!((step, reply_lost), (1, false), "{label}");
                assert!(!pending.landed, "{label}");
                net.heal_now(0);
                net.resolve_seal(pending.host, pending.session, pending.landed).unwrap();
                assert!(!net.host(0).is_sealed(1), "{label}");
                serves(net.host_mut(0), 1);
            }
            HandshakeOutcome::MovedSealed(pending) => {
                // Step 3: the move happened; the target is authoritative
                // and the sealed source copy is released by the retried
                // resolution once the link heals.
                assert_eq!(step, 3, "{label}");
                assert!(pending.landed, "{label}");
                assert!(net.host(1).contains(1), "{label}");
                if !reply_lost {
                    // The forget never arrived: the source copy is
                    // sealed, refusing ops with the typed marker.
                    let err = net.host_mut(0).begin_think(1, 4).unwrap_err();
                    assert!(
                        err.downcast_ref::<Recovering>().is_some(),
                        "{label}: got {err:#}"
                    );
                    net.heal_now(0);
                }
                // Retry the pending resolution; a definitive "unknown
                // session" (the forget had landed, reply lost) retires
                // it just the same.
                let _ = net.resolve_seal(pending.host, pending.session, pending.landed);
                assert!(!net.host(0).contains(1), "{label}");
                serves(net.host_mut(1), 1);
            }
        }
    }
}

/// Golden-trace determinism of the fault layer itself: same hosts, same
/// script ⇒ byte-identical event log.
#[test]
fn fake_host_net_event_log_is_golden() {
    let run = |seed: u64| {
        let mut a = FakeHost::new(1, 2, LatencyScript::uniform(seed, (1, 3), (2, 7)));
        a.open(1, &env(seed), spec(12, seed), 1.0).unwrap();
        a.begin_think(1, 12).unwrap();
        a.run_to_completion();
        let b = FakeHost::new(1, 2, LatencyScript::uniform(seed ^ 9, (1, 3), (2, 7)));
        let mut net = FakeHostNet::new(vec![a, b]);
        net.delay_at(2, 5);
        net.drop_reply_at(3);
        let _ = migrate_over(&mut net, 1, 0, 1);
        net.sever_now(1);
        net.heal_now(1);
        let mut log = net.take_log();
        // Fold in the hosts' own golden traces so the claim covers the
        // scripted services, not just the message layer.
        log.push(format!("host0-trace-lines={}", net.host_mut(0).svc().trace().len()));
        log.push(format!("host1-trace-lines={}", net.host_mut(1).svc().trace().len()));
        log
    };
    let first = run(31);
    assert_eq!(first, run(31), "same seed ⇒ identical event log");
    assert!(
        first.iter().any(|l| l.contains("delay")) && first.iter().any(|l| l.contains("REPLY-LOST")),
        "script must actually exercise delay and reply loss: {first:#?}"
    );
    assert_ne!(first, run(32), "different seeds script different runs");
}

// ---------------------------------------------------------------------
// 2. Wire layer: the four host ops over the real dispatcher
// ---------------------------------------------------------------------

fn sharded(cap: Option<usize>) -> ShardedService {
    ShardedService::start(ShardedConfig {
        shards: 2,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        max_sessions_per_shard: cap,
        ..ShardedConfig::default()
    })
}

fn ok(line: &str) -> Json {
    let v = Json::parse(line).expect("reply is valid json");
    assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(true), "line: {line}");
    assert_eq!(Json::parse(line).unwrap().render(), line, "stable round-trip: {line}");
    v
}

fn err(line: &str) -> Json {
    let v = Json::parse(line).expect("error replies are json");
    assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(false), "line: {line}");
    assert!(v.get("error").and_then(|e| e.as_str()).is_some(), "line: {line}");
    v
}

#[test]
fn wire_export_import_install_roundtrip_between_services() {
    let a = sharded(None);
    let b = sharded(None);
    let ha = a.handle();
    let hb = b.handle();
    let (line, _) =
        handle_line(&ha, r#"{"op":"open","env":"garnet","seed":5,"sims":12,"rollout":8}"#);
    let sid = ok(&line).get("session").unwrap().as_u64().unwrap();
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"think","session":{sid}}}"#));
    assert_eq!(ok(&line).get("quiescent").unwrap().as_bool(), Some(true));
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"best","session":{sid}}}"#));
    let best = ok(&line).get("action").unwrap().as_u64().unwrap();

    // export: hex frame + seal
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"export","session":{sid}}}"#));
    let v = ok(&line);
    assert_eq!(v.get("session").unwrap().as_u64(), Some(sid));
    let frame = v.get("image").unwrap().as_str().unwrap().to_string();
    assert!(!frame.is_empty() && frame.len() % 2 == 0);
    assert!(image_from_hex(&frame).is_ok(), "frame must decode");
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"think","session":{sid}}}"#));
    let v = err(&line);
    assert_eq!(v.get("recovering").and_then(|r| r.as_bool()), Some(true), "sealed: {line}");

    // double export of a sealed session is a refusal, not a second seal
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"export","session":{sid}}}"#));
    assert_eq!(err(&line).get("recovering").and_then(|r| r.as_bool()), Some(true));

    // import on the second service: same id, same recommendation
    let (line, _) = handle_line(&hb, &format!(r#"{{"op":"import","image":"{frame}"}}"#));
    assert_eq!(ok(&line).get("session").unwrap().as_u64(), Some(sid));
    let (line, _) = handle_line(&hb, &format!(r#"{{"op":"best","session":{sid}}}"#));
    assert_eq!(ok(&line).get("action").unwrap().as_u64(), Some(best), "tree moved bit-for-bit");

    // a duplicate import on the target is refused
    let (line, _) = handle_line(&hb, &format!(r#"{{"op":"import","image":"{frame}"}}"#));
    err(&line);

    // resolve the seal: the source forgets, the target serves on
    let (line, _) =
        handle_line(&ha, &format!(r#"{{"op":"install","session":{sid},"landed":true}}"#));
    let v = ok(&line);
    assert_eq!(v.get("landed").unwrap().as_bool(), Some(true));
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"best","session":{sid}}}"#));
    err(&line);
    let (line, _) = handle_line(&hb, &format!(r#"{{"op":"think","session":{sid}}}"#));
    assert_eq!(ok(&line).get("quiescent").unwrap().as_bool(), Some(true));
    let (line, _) = handle_line(&hb, &format!(r#"{{"op":"close","session":{sid}}}"#));
    assert_eq!(ok(&line).get("unobserved").unwrap().as_u64(), Some(0));
}

#[test]
fn wire_unseal_after_refused_transfer_restores_service() {
    let a = sharded(None);
    let ha = a.handle();
    let (line, _) = handle_line(&ha, r#"{"op":"open","env":"garnet","seed":9,"sims":8}"#);
    let sid = ok(&line).get("session").unwrap().as_u64().unwrap();
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"export","session":{sid}}}"#));
    ok(&line);
    let (line, _) =
        handle_line(&ha, &format!(r#"{{"op":"install","session":{sid},"landed":false}}"#));
    assert_eq!(ok(&line).get("landed").unwrap().as_bool(), Some(false));
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"think","session":{sid}}}"#));
    assert_eq!(ok(&line).get("quiescent").unwrap().as_bool(), Some(true), "unsealed: {line}");
    // Unsealing an unsealed session stays a no-op.
    let (line, _) =
        handle_line(&ha, &format!(r#"{{"op":"install","session":{sid},"landed":false}}"#));
    ok(&line);
    let (line, _) = handle_line(&ha, &format!(r#"{{"op":"close","session":{sid}}}"#));
    ok(&line);
}

#[test]
fn wire_image_frame_failures_are_typed_and_nonfatal() {
    let svc = sharded(None);
    let h = svc.handle();
    for (frame, needle) in [
        ("abc", "odd hex length"),      // truncated mid-byte
        ("zz", "non-hex byte"),         // not hex at all
        ("00ff00", "magic"),            // valid hex, not a session image
    ] {
        let (line, _) = handle_line(&h, &format!(r#"{{"op":"import","image":"{frame}"}}"#));
        let v = err(&line);
        let msg = v.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains(needle), "frame {frame:?}: error {msg:?} should contain {needle:?}");
    }
    // A truncated *valid-hex* image is a typed store error, not a panic:
    // export a real session and cut the frame.
    let (line, _) = handle_line(&h, r#"{"op":"open","env":"garnet","seed":2,"sims":8}"#);
    let sid = ok(&line).get("session").unwrap().as_u64().unwrap();
    let (line, _) = handle_line(&h, &format!(r#"{{"op":"export","session":{sid}}}"#));
    let frame = ok(&line).get("image").unwrap().as_str().unwrap().to_string();
    let cut = &frame[..(frame.len() / 2) & !1usize];
    let (line, _) = handle_line(&h, &format!(r#"{{"op":"import","image":"{cut}"}}"#));
    err(&line);
    // The dispatcher survives all of it.
    let (line, _) = handle_line(&h, r#"{"op":"ping"}"#);
    ok(&line);
}

#[test]
fn wire_health_on_a_shard_host_reports_the_host_role() {
    let svc = sharded(None);
    let h = svc.handle();
    let (line, _) = handle_line(&h, r#"{"op":"open","env":"garnet","seed":3,"sims":8}"#);
    let sid = ok(&line).get("session").unwrap().as_u64().unwrap();
    let (line, _) = handle_line(&h, r#"{"op":"health"}"#);
    let v = ok(&line);
    assert_eq!(v.get("role").unwrap().as_str(), Some("host"));
    assert_eq!(v.get("shards").unwrap().as_u64(), Some(2));
    assert_eq!(v.get("sessions_open").unwrap().as_u64(), Some(1));
    let Some(Json::Arr(sessions)) = v.get("sessions") else {
        panic!("host health must list sessions: {line}");
    };
    assert_eq!(sessions[0].get("id").unwrap().as_u64(), Some(sid));
    assert_eq!(sessions[0].get("sealed").unwrap().as_bool(), Some(false));
    // An export flips the health entry's sealed flag — the signal a
    // restarted router uses to release copies stuck mid-hand-off.
    let (line, _) = handle_line(&h, &format!(r#"{{"op":"export","session":{sid}}}"#));
    ok(&line);
    let (line, _) = handle_line(&h, r#"{"op":"health"}"#);
    let v = ok(&line);
    let Some(Json::Arr(sessions)) = v.get("sessions") else {
        panic!("host health must list sessions: {line}");
    };
    assert_eq!(sessions[0].get("sealed").unwrap().as_bool(), Some(true));
    let (line, _) =
        handle_line(&h, &format!(r#"{{"op":"install","session":{sid},"landed":false}}"#));
    ok(&line);
    let (line, _) = handle_line(&h, &format!(r#"{{"op":"close","session":{sid}}}"#));
    ok(&line);
}

// ---------------------------------------------------------------------
// 3. Live TCP: router over two in-process shard-host services
// ---------------------------------------------------------------------

fn host_service(cap: Option<usize>) -> (ShardedService, TcpServer, String) {
    let svc = ShardedService::start(ShardedConfig {
        shards: 1,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        max_sessions_per_shard: cap,
        ..ShardedConfig::default()
    });
    let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    (svc, server, addr)
}

#[test]
fn router_proxies_migrates_and_survives_a_killed_host() {
    let (svc_a, srv_a, addr_a) = host_service(None);
    let (svc_b, srv_b, addr_b) = host_service(None);
    let router = Router::start(RouterConfig::new(vec![addr_a, addr_b])).unwrap();
    let rh = router.handle();

    // Open until host 0 holds two sessions (one to migrate away, one to
    // observe the outage with) and host 1 holds one — placement is the
    // pure ring function of the router-drawn id.
    let mut sids = Vec::new();
    for i in 0..64u64 {
        let seed = 300 + i;
        sids.push(rh.open(Box::new(env(seed)), spec(12, seed), opts(seed)).unwrap());
        let on_host0 = sids.iter().filter(|&&s| rh.host_of(s) == 0).count();
        let on_host1 = sids.iter().filter(|&&s| rh.host_of(s) == 1).count();
        if on_host0 >= 2 && on_host1 >= 1 {
            break;
        }
    }
    let on0 = *sids.iter().find(|&&s| rh.host_of(s) == 0).expect("a session on host 0");
    let on1 = *sids.iter().find(|&&s| rh.host_of(s) == 1).expect("a session on host 1");

    let t = rh.think(on0, 12).unwrap();
    assert!(t.quiescent, "ΣO = 0 over the proxied wire path");
    rh.think(on1, 12).unwrap();
    let best_before = rh.best_action(on0).unwrap();

    // Cross-host live migration via the wire handshake.
    let m = rh.migrate(on0, 1).unwrap();
    assert!(m.moved);
    assert_eq!((m.from, m.to), (0, 1));
    assert_eq!(rh.host_of(on0), 1, "override must repoint routing");
    assert_eq!(rh.best_action(on0).unwrap(), best_before, "tree crossed processes bit-for-bit");
    let t2 = rh.think(on0, 12).unwrap();
    assert!(t2.quiescent, "the migrated session keeps searching on host B");

    // Metrics see both tiers.
    let reports = rh.host_metrics().unwrap();
    assert_eq!(reports.len(), 2);
    assert!(reports.iter().all(|r| r.reachable));
    assert_eq!(reports[1].metrics.migrations_in, 1);
    assert_eq!(reports[0].metrics.migrations_out, 1);
    let fleet = rh.metrics().unwrap();
    assert_eq!(fleet.hosts, 2);
    assert_eq!(fleet.host_unreachable, 0);

    // Router health over the wire dispatcher.
    let (line, _) = handle_line(&rh, r#"{"op":"health"}"#);
    let v = ok(&line);
    assert_eq!(v.get("role").unwrap().as_str(), Some("router"));
    assert_eq!(v.get("hosts").unwrap().as_u64(), Some(2));

    // Kill host A; its sessions go unreachable, host B keeps serving.
    drop(srv_a);
    drop(svc_a);
    let mut dead = None;
    for &s in &sids {
        if s != on0 && rh.host_of(s) == 0 {
            dead = Some(s);
            break;
        }
    }
    let dead = dead.expect("another session lived on host 0");
    let e = rh.think(dead, 8).unwrap_err();
    assert!(e.downcast_ref::<HostUnreachable>().is_some(), "got: {e:#}");
    assert!(rh.host_unreachable() >= 1);
    let t3 = rh.think(on0, 8).unwrap();
    assert!(t3.quiescent, "the surviving host's sessions still serve");
    let h = rh.health().unwrap();
    assert!(!h.host_status[0].reachable);
    assert!(h.host_status[1].reachable);
    let fleet = rh.metrics().unwrap();
    assert!(fleet.host_unreachable >= 1, "metrics must report the unreachable host");

    rh.close(on0).unwrap();
    rh.close(on1).unwrap();
    drop(srv_b);
    drop(svc_b);
}

/// Satellite regression: a `Busy` (or any refused) reply to an in-flight
/// *remote* import must not leak the sealed source copy — the router
/// aborts, the source unseals, and the session serves again.
#[test]
fn refused_remote_import_unseals_the_source() {
    let (svc_a, _srv_a, addr_a) = host_service(None);
    let (svc_b, _srv_b, addr_b) = host_service(Some(1));
    // Fill host B to its cap directly.
    let hb = svc_b.handle();
    let filler = hb.open(Box::new(env(401)), spec(8, 401), opts(401)).unwrap();
    let router = Router::start(RouterConfig::new(vec![addr_a, addr_b])).unwrap();
    let rh = router.handle();
    // Host B is full, so the router's open lands on host A.
    let sid = rh.open(Box::new(env(402)), spec(12, 402), opts(402)).unwrap();
    assert_eq!(rh.host_of(sid), 0);
    rh.think(sid, 8).unwrap();
    let e = rh.migrate(sid, 1).expect_err("full target must refuse the import");
    assert!(e.downcast_ref::<Busy>().is_some(), "expected Busy, got: {e:#}");
    let t = rh.think(sid, 8).unwrap();
    assert!(t.quiescent, "refused remote import must leave the source serving");
    assert_eq!(rh.host_of(sid), 0, "routing still points at the source");
    rh.close(sid).unwrap();
    hb.close(filler).unwrap();
    drop(svc_a);
}

#[test]
fn a_restarted_router_relearns_placement_and_id_floor() {
    let (svc_a, _srv_a, addr_a) = host_service(None);
    let (svc_b, _srv_b, addr_b) = host_service(None);
    let first = Router::start(RouterConfig::new(vec![addr_a.clone(), addr_b.clone()])).unwrap();
    let h1 = first.handle();
    let sid = h1.open(Box::new(env(501)), spec(12, 501), opts(501)).unwrap();
    h1.think(sid, 12).unwrap();
    let to = 1 - h1.host_of(sid);
    h1.migrate(sid, to).unwrap();
    let best = h1.best_action(sid).unwrap();
    drop(first); // the router dies; the hosts keep the sessions

    let second = Router::start(RouterConfig::new(vec![addr_a, addr_b])).unwrap();
    let h2 = second.handle();
    assert_eq!(h2.host_of(sid), to, "override re-learned from health probes");
    assert_eq!(h2.best_action(sid).unwrap(), best);
    let fresh = h2.open(Box::new(env(502)), spec(8, 502), opts(502)).unwrap();
    assert!(fresh > sid, "id floor resumes past live ids");
    let t = h2.think(sid, 8).unwrap();
    assert!(t.quiescent);
    h2.close(fresh).unwrap();
    h2.close(sid).unwrap();
    drop(svc_a);
    drop(svc_b);
}

// ---------------------------------------------------------------------
// 4. Chaos: seeded fault schedules over whole deployments
// ---------------------------------------------------------------------

/// The headline sweep: 200 seeds, each a full deployment (two durable
/// hosts, standby stream, two lease-fenced routers) under a schedule of
/// faults that is a pure function of the seed, with the global
/// invariants — no session lost, at most one unsealed copy, `ΣO = 0`,
/// survivor `best` equal to an unfaulted control — checked after every
/// op. Zero violations, every seed.
#[test]
fn chaos_sweep_holds_every_invariant_across_two_hundred_seeds() {
    for seed in 0..200u64 {
        let r = run_chaos(seed, 10).unwrap();
        assert!(
            r.violations.is_empty(),
            "seed {seed}: {:?}\nschedule: {:?}\nlog tail: {:#?}",
            r.violations,
            r.schedule,
            &r.log[r.log.len().saturating_sub(16)..]
        );
    }
}

/// Schedules are derived from the seed alone — regenerating one later
/// (to replay or shrink a failure) yields the same script.
#[test]
fn chaos_schedules_are_pure_functions_of_the_seed() {
    for seed in [0u64, 7, 41, 1999] {
        assert_eq!(chaos_schedule(seed, 40), chaos_schedule(seed, 40));
    }
    assert_ne!(chaos_schedule(1, 40), chaos_schedule(2, 40));
    // A longer schedule extends the shorter one's prefix: lengths don't
    // reshuffle history, so a failure can be re-cut at any length.
    let long = chaos_schedule(3, 40);
    assert_eq!(long[..12], chaos_schedule(3, 12)[..]);
}

/// The regression corpus: minimal failing schedules (found by the
/// scheduler and shrunk with [`shrink_chaos`]) replayed against builds
/// with the corresponding guard deliberately reverted. Each entry must
/// reproduce the original failure shape with the guard off and pass
/// clean with defenses on — proving the chaos harness actually detects
/// the bug class each guard exists for.
#[test]
fn chaos_regression_corpus_replays_failure_shapes_against_reverted_guards() {
    struct Entry {
        name: &'static str,
        seed: u64,
        script: Vec<ChaosOp>,
        reverted: Guards,
        shape: &'static str,
    }
    let corpus = [
        // A router stalls past its lease TTL mid-migration; without
        // epoch fencing it applies the stale placement and the session
        // ends up with two unsealed copies.
        Entry {
            name: "stale-lease-placement",
            seed: 5,
            script: vec![ChaosOp::LeaseClash { session: 1, router: 0 }],
            reverted: Guards { lease_fencing: false, ..Guards::default() },
            shape: "unsealed copies",
        },
        // A crash after a migrated-away session's WAL `Close` was lost
        // with the unsynced suffix revives the forgotten copy; without
        // the post-crash repair pass it stays live alongside the real
        // one.
        Entry {
            name: "revived-copy-after-crash",
            seed: 3,
            script: vec![
                ChaosOp::Migrate { session: 1, router: 0 },
                ChaosOp::Crash { host: 0 },
            ],
            reverted: Guards { repair_after_crash: false, ..Guards::default() },
            shape: "unsealed copies",
        },
    ];
    for e in &corpus {
        let broken = replay_chaos(e.seed, &e.script, e.reverted).unwrap();
        assert!(
            broken.violations.iter().any(|v| v.contains(e.shape)),
            "{}: reverted guard must reproduce {:?}, got {:?}",
            e.name,
            e.shape,
            broken.violations
        );
        let guarded = replay_chaos(e.seed, &e.script, Guards::default()).unwrap();
        assert!(
            guarded.violations.is_empty(),
            "{}: guards on must pass clean, got {:?}",
            e.name,
            guarded.violations
        );
        // The corpus stores minimal scripts: shrinking is a fixpoint.
        let min = shrink_chaos(e.seed, &e.script, e.reverted).unwrap();
        assert_eq!(min, e.script, "{}: corpus entry should already be minimal", e.name);
    }
}

/// The crash-at-every-step matrix: around the two delicate multi-step
/// protocols — lease handover (seal, stall, takeover, unseal) and
/// standby promotion (sync, ship, fold) — inject every fault kind at
/// every schedule position. With all guards on, every combination must
/// hold every invariant.
#[test]
fn chaos_crash_at_every_step_matrix_for_handover_and_promotion() {
    let bases: [(&str, Vec<ChaosOp>); 2] = [
        (
            "lease-handover",
            vec![
                ChaosOp::Think { session: 1 },
                ChaosOp::Sync { host: 0 },
                ChaosOp::LeaseClash { session: 1, router: 0 },
                ChaosOp::Think { session: 1 },
            ],
        ),
        (
            "standby-promotion",
            vec![
                ChaosOp::Think { session: 1 },
                ChaosOp::Sync { host: 0 },
                ChaosOp::ReplShip,
                ChaosOp::Promote,
                ChaosOp::Think { session: 1 },
            ],
        ),
    ];
    // Request lost (severed link), reply lost, host crash — on both
    // hosts — plus the replication lane's own partition.
    let faults = [
        ChaosOp::Sever { host: 0 },
        ChaosOp::Sever { host: 1 },
        ChaosOp::DropNextReply,
        ChaosOp::Crash { host: 0 },
        ChaosOp::Crash { host: 1 },
        ChaosOp::SeverStandby,
    ];
    for (name, base) in &bases {
        for pos in 0..=base.len() {
            for &fault in &faults {
                let mut script = base.clone();
                script.insert(pos, fault);
                let r = replay_chaos(17, &script, Guards::default()).unwrap();
                assert!(
                    r.violations.is_empty(),
                    "{name} pos={pos} fault={fault:?}: {:?}\nlog tail: {:#?}",
                    r.violations,
                    &r.log[r.log.len().saturating_sub(16)..]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// 5. Control plane over live TCP
// ---------------------------------------------------------------------

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("wuuct-dist-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Count live (unsealed) copies of a session across the host fleet —
/// the duplication/loss check for the hot-hot router test.
fn live_copies(hosts: &[&ShardedService], sid: u64) -> usize {
    hosts
        .iter()
        .flat_map(|svc| svc.handle().health().unwrap().sessions)
        .filter(|s| s.id == sid && !s.sealed)
        .count()
}

/// Two hot-hot routers over one host fleet, sharing one lease table:
/// while a peer holds a session's lease, a racing router's migrate
/// fails with the typed [`LeaseLost`] — and the session is neither
/// duplicated nor lost. Once the lease frees, the move goes through.
#[test]
fn hot_hot_routers_share_leases_and_the_loser_sees_lease_lost() {
    let (svc_a, _srv_a, addr_a) = host_service(None);
    let (svc_b, _srv_b, addr_b) = host_service(None);
    let leases = LeaseTable::new(60_000);
    let cfg = |table: LeaseTable| RouterConfig {
        leases: Some(table),
        ..RouterConfig::new(vec![addr_a.clone(), addr_b.clone()])
    };
    let r1 = Router::start(cfg(leases.clone())).unwrap();
    let r2 = Router::start(cfg(leases.clone())).unwrap();
    let h1 = r1.handle();
    let h2 = r2.handle();

    // Both routers serve the same session hot-hot.
    let sid = h1.open(Box::new(env(601)), spec(12, 601), opts(601)).unwrap();
    assert!(h1.think(sid, 12).unwrap().quiescent);
    assert!(h2.think(sid, 12).unwrap().quiescent, "peer router serves the same session");
    let to = 1 - h1.host_of(sid);

    // A stalled peer holds the session's lease (owner token no router
    // uses, acquired far enough into the shared clock that it cannot
    // have expired): the racing migrate must lose with the typed error,
    // not block, not duplicate.
    let stale = leases.acquire(sid, 0xDEAD_BEEF, 30_000).expect("free lease");
    let e = h1.migrate(sid, to).expect_err("leased elsewhere");
    assert!(e.downcast_ref::<LeaseLost>().is_some(), "expected LeaseLost, got: {e:#}");
    assert_eq!(live_copies(&[&svc_a, &svc_b], sid), 1, "no duplicate, no loss");
    assert!(h2.think(sid, 8).unwrap().quiescent, "the session kept serving throughout");

    // Lease freed: the same move now completes under the peer router.
    leases.release(stale);
    let m = h2.migrate(sid, to).unwrap();
    assert!(m.moved);
    assert_eq!(live_copies(&[&svc_a, &svc_b], sid), 1, "exactly one copy after the move");
    assert!(h2.think(sid, 8).unwrap().quiescent);
    h2.close(sid).unwrap();
    drop(svc_a);
    drop(svc_b);
}

/// The standby-promotion acceptance: a durable primary streaming its
/// WALs to a standby over real TCP (`--replicate` + `--repl-ack`) dies
/// abruptly; promoting the standby folds the replicated streams into
/// live sessions that match the primary's pre-death recommendations
/// node for node, then serve on.
#[test]
fn killed_replicated_primary_promotes_standby_node_for_node() {
    let dir = temp_dir("repl-promote");
    // Standby: an ordinary sharded service behind real TCP — the
    // primary's streamer threads speak the `replicate` wire op at it.
    let standby = ShardedService::start(ShardedConfig {
        shards: 2,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let standby_srv = TcpServer::bind(standby.handle(), "127.0.0.1:0").unwrap();
    let standby_addr = standby_srv.local_addr().to_string();

    let primary = ShardedService::start_durable(ShardedConfig {
        shards: 2,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        data_dir: Some(dir.clone()),
        snapshot_every: 1,
        full_every: 4,
        replicate: Some(standby_addr),
        repl_ack: true,
        ..ShardedConfig::default()
    })
    .unwrap();
    let hp = primary.handle();
    let mut control = Vec::new();
    for i in 0..3u64 {
        let seed = 700 + i;
        let sid = hp.open(Box::new(env(seed)), spec(12, seed), opts(seed)).unwrap();
        let t = hp.think(sid, 12).unwrap();
        assert!(t.quiescent);
        control.push((sid, hp.best_action(sid).unwrap()));
    }
    // `--repl-ack` is the determinism here: every reply above was held
    // until the standby acked the records behind it, so the streams are
    // caught up by construction — no polling, no sleeps.
    let hs = standby.handle();
    let status = hs.replicate_status().unwrap();
    assert!(
        status.iter().any(|s| s.acked > 0),
        "standby must have acked replicated records: {status:?}"
    );

    // The primary dies with no orderly drain of its sessions — every
    // record that matters is already on the standby (that is what the
    // acks meant).
    drop(primary);

    let reply = hs.promote().unwrap();
    assert_eq!(reply.sessions, 3, "every replicated session promoted");
    for &(sid, best) in &control {
        assert_eq!(
            hs.best_action(sid).unwrap(),
            best,
            "session {sid}: the promoted tree must recommend exactly what the primary did"
        );
        let t = hs.think(sid, 8).unwrap();
        assert!(t.quiescent, "session {sid} serves on after promotion");
        assert_eq!(hs.close(sid).unwrap().unobserved, 0);
    }
    drop(standby_srv);
    drop(standby);
    let _ = std::fs::remove_dir_all(&dir);
}
