//! Integration tests for the multi-session search service — including the
//! headline acceptance criterion: ≥ 32 concurrent sessions over shared
//! pools, each episode's return within noise of a dedicated-pool WU-UCT
//! baseline on the same seeds, with per-session quiescence (`ΣO = 0`).
//!
//! Also the control plane's wire layer: `join` / `heartbeat` / `drain`
//! round-trip against a live dynamic-fleet router over real TCP, and
//! `replicate` / `repl_status` / `promote` stream a WAL frame onto a
//! standby host — with torn, corrupt, and oversized frames rejected as
//! typed error replies, never a dropped connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use wu_uct::env::garnet::Garnet;
use wu_uct::env::Env;
use wu_uct::mcts::{Search, SearchSpec, WuUct};
use wu_uct::service::json::Json;
use wu_uct::service::proto::handle_line;
use wu_uct::service::{
    HostClient, Router, RouterConfig, SearchService, ServiceConfig, SessionOptions, ShardedConfig,
    ShardedService, TcpServer,
};
use wu_uct::store::{encode_frame, Record, MAX_FRAME_BYTES};
use wu_uct::util::stats::{mean, std_dev};

const SIMS: u32 = 24;
const MAX_STEPS: usize = 30;

fn episode_spec(seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: SIMS,
        rollout_limit: 8,
        max_depth: 12,
        seed,
        ..SearchSpec::default()
    }
}

fn garnet(seed: u64) -> Garnet {
    // Must match the protocol's "garnet" construction (service::proto).
    Garnet::new(15, 3, 30, 0.0, seed)
}

/// Classic one-user-per-pool-set episode: the quality baseline.
fn dedicated_episode(seed: u64) -> f64 {
    let mut env = garnet(seed);
    let mut search = WuUct::new(episode_spec(seed), 1, 2);
    let mut total = 0.0;
    for _ in 0..MAX_STEPS {
        if env.is_terminal() {
            break;
        }
        let r = search.search(&env);
        let legal = env.legal_actions();
        let a = if legal.contains(&r.best_action) { r.best_action } else { legal[0] };
        let step = env.step(a);
        total += step.reward;
        if step.done {
            break;
        }
    }
    total
}

fn request(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, line: &str) -> Json {
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).expect("valid json reply");
    assert_eq!(
        v.get("ok").and_then(|o| o.as_bool()),
        Some(true),
        "server error on {line}: {reply}"
    );
    v
}

/// One full episode through the TCP protocol; returns the episode reward.
/// Asserts the per-session quiescence invariant after every think and at
/// close.
fn served_episode(addr: &str, seed: u64) -> f64 {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let v = request(
        &mut reader,
        &mut writer,
        &format!(
            r#"{{"op":"open","env":"garnet","seed":{seed},"sims":{SIMS},"rollout":8,"depth":12}}"#
        ),
    );
    let sid = v.get("session").unwrap().as_u64().unwrap();
    let mut total = 0.0;
    for _ in 0..MAX_STEPS {
        let t = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
        assert_eq!(
            t.get("quiescent").unwrap().as_bool(),
            Some(true),
            "ΣO != 0 after a think on session {sid}"
        );
        let action = t.get("action").unwrap().as_u64().unwrap();
        let a = request(
            &mut reader,
            &mut writer,
            &format!(r#"{{"op":"advance","session":{sid},"action":{action}}}"#),
        );
        total += a.get("reward").unwrap().as_f64().unwrap();
        if a.get("done").unwrap().as_bool() == Some(true) {
            break;
        }
    }
    let c = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
    assert_eq!(c.get("unobserved").unwrap().as_u64(), Some(0), "ΣO != 0 at close");
    total
}

#[test]
fn serve_32_concurrent_sessions_matches_dedicated_baseline() {
    const SESSIONS: usize = 32;
    let seeds: Vec<u64> = (0..SESSIONS as u64).map(|i| 1000 + i * 7919).collect();

    // Baseline: each seed planned with its own dedicated pools.
    let dedicated: Vec<f64> = seeds.iter().map(|&s| dedicated_episode(s)).collect();

    // Service: the same seeds, all sharing 2 + 8 workers.
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 2,
        simulation_workers: 8,
        ..ServiceConfig::default()
    });
    let server = TcpServer::bind(service.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let served: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = seeds
            .iter()
            .map(|&s| {
                let addr = addr.clone();
                scope.spawn(move || served_episode(&addr, s))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });

    let m = service.handle().metrics().unwrap();
    assert_eq!(m.sessions_opened, SESSIONS as u64);
    assert_eq!(m.sessions_closed, SESSIONS as u64, "every session closed cleanly");
    assert!(m.thinks >= SESSIONS as u64);

    // Quality parity: same seeds, same budgets — the shared-pool mean must
    // sit within search noise of the dedicated-pool mean. Both planners
    // are stochastic, so "noise" is measured from the baseline's own
    // spread across seeds.
    let md = mean(&dedicated);
    let ms = mean(&served);
    let sd = std_dev(&dedicated).max(std_dev(&served));
    let tolerance = 0.75 * sd + 0.10 * md.abs() + 0.5;
    assert!(
        (md - ms).abs() <= tolerance,
        "shared-pool mean {ms:.3} vs dedicated mean {md:.3} (tolerance {tolerance:.3})"
    );
}

#[test]
fn sharded_serve_runs_concurrent_episodes_over_tcp() {
    // The tentpole end-to-end: 16 concurrent episodes against a 4-shard
    // service behind the real TCP protocol. Placement is by consistent
    // hash, so sessions spread over shards; every episode must preserve
    // the per-session quiescence invariant regardless of which shard's
    // pool ran each simulation (stealing enabled, tiny pools to force
    // overflow).
    const SESSIONS: usize = 16;
    let service = ShardedService::start(ShardedConfig {
        shards: 4,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let server = TcpServer::bind(service.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let rewards: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..SESSIONS as u64)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || served_episode(&addr, 3000 + i * 101))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });
    assert!(rewards.iter().all(|r| r.is_finite()));

    let h = service.handle();
    let m = h.metrics().unwrap();
    assert_eq!(m.shards, 4);
    assert_eq!(m.sessions_opened, SESSIONS as u64);
    assert_eq!(m.sessions_closed, SESSIONS as u64);
    assert_eq!(m.sessions_open, 0);
    // Sessions actually spread: no single shard served everything.
    let per_shard = h.shard_metrics().unwrap();
    assert_eq!(per_shard.len(), 4);
    let busiest = per_shard.iter().map(|m| m.sessions_opened).max().unwrap();
    assert!(
        busiest < SESSIONS as u64,
        "all {SESSIONS} sessions landed on one shard"
    );
    // Steal-queue accounting balances: every shed task was executed
    // somewhere (or reclaimed locally), never lost.
    assert!(m.sims_stolen <= m.sims_shed);
}

#[test]
fn tree_reuse_carries_statistics_across_moves() {
    // In-process: think hard, advance along the chosen action, and verify
    // the next root starts warm (subtree was reused, not rebuilt).
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 1,
        simulation_workers: 4,
        ..ServiceConfig::default()
    });
    let h = service.handle();
    let env = Box::new(garnet(7));
    let sid = h.open(env, episode_spec(7), SessionOptions::default()).unwrap();
    let t = h.think(sid, 64).unwrap();
    assert!(t.tree_size > 2);
    let adv = h.advance(sid, t.action).unwrap();
    assert!(adv.reused, "the searched best action must have an expanded subtree");
    assert!(adv.retained > 0);
    // A follow-up think still works and stays quiescent on the reused tree.
    let t2 = h.think(sid, 16).unwrap();
    assert!(t2.quiescent);
    let c = h.close(sid).unwrap();
    assert_eq!(c.unobserved, 0);
    assert_eq!(c.thinks, 2);
}

#[test]
fn fair_scheduling_serves_unequal_budgets_concurrently() {
    // A big-budget session must not starve small-budget sessions: open one
    // heavy and several light sessions simultaneously; all must finish.
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 1,
        simulation_workers: 2,
        ..ServiceConfig::default()
    });
    std::thread::scope(|scope| {
        let heavy = service.handle();
        scope.spawn(move || {
            let sid = heavy
                .open(Box::new(garnet(50)), episode_spec(50), SessionOptions::default())
                .unwrap();
            let t = heavy.think(sid, 400).unwrap();
            assert_eq!(t.sims, 400);
            heavy.close(sid).unwrap();
        });
        for i in 0..4 {
            let light = service.handle();
            scope.spawn(move || {
                let seed = 60 + i;
                let sid = light
                    .open(Box::new(garnet(seed)), episode_spec(seed), SessionOptions::default())
                    .unwrap();
                for _ in 0..3 {
                    let t = light.think(sid, 8).unwrap();
                    assert!(t.quiescent);
                }
                light.close(sid).unwrap();
            });
        }
    });
    let m = service.handle().metrics().unwrap();
    assert_eq!(m.sessions_closed, 5);
    assert_eq!(m.sims, 400 + 4 * 3 * 8);
}

// ---------------------------------------------------------------------
// Control-plane wire layer
// ---------------------------------------------------------------------

/// Like [`request`], but expects an error reply and returns its message.
fn request_err(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, line: &str) -> String {
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).expect("valid json reply");
    assert_eq!(
        v.get("ok").and_then(|o| o.as_bool()),
        Some(false),
        "expected an error reply on {line}: {reply}"
    );
    v.get("error").and_then(|e| e.as_str()).expect("error message").to_string()
}

fn shard_host() -> (ShardedService, TcpServer, String) {
    let svc = ShardedService::start(ShardedConfig {
        shards: 1,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    (svc, server, addr)
}

/// The membership ops over real TCP against a dynamic-fleet router:
/// `join` registers (then idempotently re-registers) a host with a
/// monotone epoch, `heartbeat` distinguishes known from unknown members,
/// and `drain` migrates the member's sessions away before forgetting it
/// — all as line-protocol round-trips.
#[test]
fn membership_wire_ops_join_heartbeat_drain_round_trip() {
    let (_svc_a, _srv_a, addr_a) = shard_host();
    let (_svc_b, _srv_b, addr_b) = shard_host();
    // Empty --hosts: the fleet is built entirely from join registrations.
    // Members here do not run heartbeat loops, so keep the failover
    // monitor from suspecting them mid-test.
    let router = Router::start(RouterConfig {
        suspect_after_ms: 600_000,
        ..RouterConfig::new(Vec::new())
    })
    .unwrap();
    let rsrv = TcpServer::bind(router.handle(), "127.0.0.1:0").unwrap();
    let stream = TcpStream::connect(rsrv.local_addr().to_string()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let j = request(&mut reader, &mut writer, &format!(r#"{{"op":"join","addr":"{addr_a}"}}"#));
    assert_eq!(j.get("outcome").and_then(|o| o.as_str()), Some("added"));
    let epoch1 = j.get("epoch").unwrap().as_u64().unwrap();
    assert!(epoch1 >= 1);

    // Idempotent re-registration (a restarted host re-joins): same
    // member, fresh epoch.
    let j2 = request(&mut reader, &mut writer, &format!(r#"{{"op":"join","addr":"{addr_a}"}}"#));
    assert_eq!(j2.get("outcome").and_then(|o| o.as_str()), Some("rejoined"));
    assert!(j2.get("epoch").unwrap().as_u64().unwrap() > epoch1);

    let hb = request(&mut reader, &mut writer, &format!(r#"{{"op":"heartbeat","addr":"{addr_a}"}}"#));
    assert_eq!(hb.get("known").and_then(|k| k.as_bool()), Some(true));
    let hb = request(
        &mut reader,
        &mut writer,
        r#"{"op":"heartbeat","addr":"203.0.113.9:1"}"#,
    );
    assert_eq!(
        hb.get("known").and_then(|k| k.as_bool()),
        Some(false),
        "an address that never joined must be told to register"
    );

    // A session served through the router lands on the only member…
    let v = request(
        &mut reader,
        &mut writer,
        r#"{"op":"open","env":"garnet","seed":4242,"sims":16,"rollout":8,"depth":12}"#,
    );
    let sid = v.get("session").unwrap().as_u64().unwrap();
    let t = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
    assert_eq!(t.get("quiescent").unwrap().as_bool(), Some(true));

    // …and drain evacuates it onto the newly joined second member, then
    // forgets the drained host entirely.
    request(&mut reader, &mut writer, &format!(r#"{{"op":"join","addr":"{addr_b}"}}"#));
    let d = request(&mut reader, &mut writer, &format!(r#"{{"op":"drain","addr":"{addr_a}"}}"#));
    assert_eq!(d.get("moved").unwrap().as_u64(), Some(1), "the session moved off the drained host");
    let hb = request(&mut reader, &mut writer, &format!(r#"{{"op":"heartbeat","addr":"{addr_a}"}}"#));
    assert_eq!(hb.get("known").and_then(|k| k.as_bool()), Some(false), "drained hosts are forgotten");

    // The session survived the drain and keeps serving through the router.
    let t = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
    assert_eq!(t.get("quiescent").unwrap().as_bool(), Some(true));
    let c = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
    assert_eq!(c.get("unobserved").unwrap().as_u64(), Some(0));

    // Malformed control requests are typed error replies.
    let e = request_err(
        &mut reader,
        &mut writer,
        &format!(r#"{{"op":"join","addr":"{addr_b}","bogus":1}}"#),
    );
    assert!(e.contains("unknown field"), "got: {e}");
    let e = request_err(&mut reader, &mut writer, r#"{"op":"drain","addr":"203.0.113.9:1"}"#);
    assert!(e.contains("never joined"), "got: {e}");
}

/// The replication ops over real TCP: a WAL frame carrying a real
/// exported session image is shipped to a standby with `replicate`,
/// acknowledged and visible in `repl_status`, and `promote` folds it
/// into a live serving session.
#[test]
fn replicate_wire_ops_ship_a_frame_and_promote_the_standby() {
    // Source: a session with real search state to export.
    let seed = 905u64;
    let source = ShardedService::start(ShardedConfig {
        shards: 1,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let hs = source.handle();
    let sid = hs
        .open(
            Box::new(garnet(seed)),
            episode_spec(seed),
            SessionOptions { env_seed: seed, ..SessionOptions::default() },
        )
        .unwrap();
    let t = hs.think(sid, 16).unwrap();
    assert!(t.quiescent);
    let best = hs.best_action(sid).unwrap();
    let image = hs.export_image(sid).unwrap();

    // Standby: an ordinary shard host behind TCP; the `replicate` op is
    // exactly what a primary's streamer threads send it.
    let (standby, _ssrv, standby_addr) = shard_host();
    let client = HostClient::new(standby_addr);
    let frame = encode_frame(9, 1, &[Record::Open { session: sid, image }]);
    assert_eq!(client.replicate(0, &frame).unwrap(), 1, "one record applied and acked");

    let status = client.repl_status().unwrap();
    assert_eq!(status.len(), 1);
    assert_eq!((status[0].shard, status[0].start, status[0].acked), (0, 9, 1));

    // A torn frame (checksum trailer cut) and a corrupted frame (payload
    // byte flipped) are typed error replies — and do not disturb the
    // already-acked stream.
    let torn = &frame[..frame.len() - 3];
    let e = client.replicate(0, torn).expect_err("torn frame must be rejected");
    assert!(format!("{e:#}").contains("checksum"), "got: {e:#}");
    let mut flipped = frame.clone();
    flipped[10] ^= 0xFF;
    let e = client.replicate(0, &flipped).expect_err("corrupt frame must be rejected");
    assert!(format!("{e:#}").contains("checksum"), "got: {e:#}");
    assert_eq!(client.repl_status().unwrap()[0].acked, 1, "bad frames acked nothing");

    // Promotion folds the replicated stream into live sessions that
    // agree with the source, node for node.
    let p = client.promote().unwrap();
    assert_eq!(p.sessions, 1);
    let hp = standby.handle();
    assert_eq!(hp.best_action(sid).unwrap(), best, "promoted tree matches the source's");
    let t = hp.think(sid, 8).unwrap();
    assert!(t.quiescent, "the promoted session serves on");
    assert_eq!(hp.close(sid).unwrap().unobserved, 0);
}

/// Frame hygiene at the dispatcher itself: odd-length, non-hex, and
/// oversized `replicate` payloads never reach the decoder — each is a
/// typed error reply naming the defect.
#[test]
fn replicate_wire_rejects_malformed_and_oversized_frames() {
    let svc = ShardedService::start(ShardedConfig {
        shards: 1,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 1,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let h = svc.handle();
    let reply = |line: &str| {
        let (out, _) = handle_line(&h, line);
        let v = Json::parse(&out).unwrap();
        assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(false), "{line} must fail: {out}");
        v.get("error").and_then(|e| e.as_str()).unwrap().to_string()
    };

    let e = reply(r#"{"op":"replicate","shard":0,"frame":"abc"}"#);
    assert!(e.contains("odd hex length"), "got: {e}");
    let e = reply(r#"{"op":"replicate","shard":0,"frame":"zz"}"#);
    assert!(e.contains("non-hex byte"), "got: {e}");

    // One hex byte past the frame cap (payload + 8-byte checksum): the
    // dispatcher rejects it before decoding or allocating record state.
    let oversized = "ab".repeat(MAX_FRAME_BYTES + 8 + 1);
    let e = reply(&format!(r#"{{"op":"replicate","shard":0,"frame":"{oversized}"}}"#));
    assert!(e.contains("oversized image frame"), "got: {e}");

    // An empty frame is well-formed hex but torn below the checksum
    // trailer: the decoder's typed truncation error surfaces.
    let e = reply(r#"{"op":"replicate","shard":0,"frame":""}"#);
    assert!(e.contains("truncated"), "got: {e}");
}
