//! Integration tests for the multi-session search service — including the
//! headline acceptance criterion: ≥ 32 concurrent sessions over shared
//! pools, each episode's return within noise of a dedicated-pool WU-UCT
//! baseline on the same seeds, with per-session quiescence (`ΣO = 0`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use wu_uct::env::garnet::Garnet;
use wu_uct::env::Env;
use wu_uct::mcts::{Search, SearchSpec, WuUct};
use wu_uct::service::json::Json;
use wu_uct::service::{
    SearchService, ServiceConfig, SessionOptions, ShardedConfig, ShardedService, TcpServer,
};
use wu_uct::util::stats::{mean, std_dev};

const SIMS: u32 = 24;
const MAX_STEPS: usize = 30;

fn episode_spec(seed: u64) -> SearchSpec {
    SearchSpec {
        max_simulations: SIMS,
        rollout_limit: 8,
        max_depth: 12,
        seed,
        ..SearchSpec::default()
    }
}

fn garnet(seed: u64) -> Garnet {
    // Must match the protocol's "garnet" construction (service::proto).
    Garnet::new(15, 3, 30, 0.0, seed)
}

/// Classic one-user-per-pool-set episode: the quality baseline.
fn dedicated_episode(seed: u64) -> f64 {
    let mut env = garnet(seed);
    let mut search = WuUct::new(episode_spec(seed), 1, 2);
    let mut total = 0.0;
    for _ in 0..MAX_STEPS {
        if env.is_terminal() {
            break;
        }
        let r = search.search(&env);
        let legal = env.legal_actions();
        let a = if legal.contains(&r.best_action) { r.best_action } else { legal[0] };
        let step = env.step(a);
        total += step.reward;
        if step.done {
            break;
        }
    }
    total
}

fn request(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, line: &str) -> Json {
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).expect("valid json reply");
    assert_eq!(
        v.get("ok").and_then(|o| o.as_bool()),
        Some(true),
        "server error on {line}: {reply}"
    );
    v
}

/// One full episode through the TCP protocol; returns the episode reward.
/// Asserts the per-session quiescence invariant after every think and at
/// close.
fn served_episode(addr: &str, seed: u64) -> f64 {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let v = request(
        &mut reader,
        &mut writer,
        &format!(
            r#"{{"op":"open","env":"garnet","seed":{seed},"sims":{SIMS},"rollout":8,"depth":12}}"#
        ),
    );
    let sid = v.get("session").unwrap().as_u64().unwrap();
    let mut total = 0.0;
    for _ in 0..MAX_STEPS {
        let t = request(&mut reader, &mut writer, &format!(r#"{{"op":"think","session":{sid}}}"#));
        assert_eq!(
            t.get("quiescent").unwrap().as_bool(),
            Some(true),
            "ΣO != 0 after a think on session {sid}"
        );
        let action = t.get("action").unwrap().as_u64().unwrap();
        let a = request(
            &mut reader,
            &mut writer,
            &format!(r#"{{"op":"advance","session":{sid},"action":{action}}}"#),
        );
        total += a.get("reward").unwrap().as_f64().unwrap();
        if a.get("done").unwrap().as_bool() == Some(true) {
            break;
        }
    }
    let c = request(&mut reader, &mut writer, &format!(r#"{{"op":"close","session":{sid}}}"#));
    assert_eq!(c.get("unobserved").unwrap().as_u64(), Some(0), "ΣO != 0 at close");
    total
}

#[test]
fn serve_32_concurrent_sessions_matches_dedicated_baseline() {
    const SESSIONS: usize = 32;
    let seeds: Vec<u64> = (0..SESSIONS as u64).map(|i| 1000 + i * 7919).collect();

    // Baseline: each seed planned with its own dedicated pools.
    let dedicated: Vec<f64> = seeds.iter().map(|&s| dedicated_episode(s)).collect();

    // Service: the same seeds, all sharing 2 + 8 workers.
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 2,
        simulation_workers: 8,
        ..ServiceConfig::default()
    });
    let server = TcpServer::bind(service.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let served: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = seeds
            .iter()
            .map(|&s| {
                let addr = addr.clone();
                scope.spawn(move || served_episode(&addr, s))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });

    let m = service.handle().metrics().unwrap();
    assert_eq!(m.sessions_opened, SESSIONS as u64);
    assert_eq!(m.sessions_closed, SESSIONS as u64, "every session closed cleanly");
    assert!(m.thinks >= SESSIONS as u64);

    // Quality parity: same seeds, same budgets — the shared-pool mean must
    // sit within search noise of the dedicated-pool mean. Both planners
    // are stochastic, so "noise" is measured from the baseline's own
    // spread across seeds.
    let md = mean(&dedicated);
    let ms = mean(&served);
    let sd = std_dev(&dedicated).max(std_dev(&served));
    let tolerance = 0.75 * sd + 0.10 * md.abs() + 0.5;
    assert!(
        (md - ms).abs() <= tolerance,
        "shared-pool mean {ms:.3} vs dedicated mean {md:.3} (tolerance {tolerance:.3})"
    );
}

#[test]
fn sharded_serve_runs_concurrent_episodes_over_tcp() {
    // The tentpole end-to-end: 16 concurrent episodes against a 4-shard
    // service behind the real TCP protocol. Placement is by consistent
    // hash, so sessions spread over shards; every episode must preserve
    // the per-session quiescence invariant regardless of which shard's
    // pool ran each simulation (stealing enabled, tiny pools to force
    // overflow).
    const SESSIONS: usize = 16;
    let service = ShardedService::start(ShardedConfig {
        shards: 4,
        shard: ServiceConfig {
            expansion_workers: 1,
            simulation_workers: 2,
            ..ServiceConfig::default()
        },
        ..ShardedConfig::default()
    });
    let server = TcpServer::bind(service.handle(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let rewards: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..SESSIONS as u64)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || served_episode(&addr, 3000 + i * 101))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });
    assert!(rewards.iter().all(|r| r.is_finite()));

    let h = service.handle();
    let m = h.metrics().unwrap();
    assert_eq!(m.shards, 4);
    assert_eq!(m.sessions_opened, SESSIONS as u64);
    assert_eq!(m.sessions_closed, SESSIONS as u64);
    assert_eq!(m.sessions_open, 0);
    // Sessions actually spread: no single shard served everything.
    let per_shard = h.shard_metrics().unwrap();
    assert_eq!(per_shard.len(), 4);
    let busiest = per_shard.iter().map(|m| m.sessions_opened).max().unwrap();
    assert!(
        busiest < SESSIONS as u64,
        "all {SESSIONS} sessions landed on one shard"
    );
    // Steal-queue accounting balances: every shed task was executed
    // somewhere (or reclaimed locally), never lost.
    assert!(m.sims_stolen <= m.sims_shed);
}

#[test]
fn tree_reuse_carries_statistics_across_moves() {
    // In-process: think hard, advance along the chosen action, and verify
    // the next root starts warm (subtree was reused, not rebuilt).
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 1,
        simulation_workers: 4,
        ..ServiceConfig::default()
    });
    let h = service.handle();
    let env = Box::new(garnet(7));
    let sid = h.open(env, episode_spec(7), SessionOptions::default()).unwrap();
    let t = h.think(sid, 64).unwrap();
    assert!(t.tree_size > 2);
    let adv = h.advance(sid, t.action).unwrap();
    assert!(adv.reused, "the searched best action must have an expanded subtree");
    assert!(adv.retained > 0);
    // A follow-up think still works and stays quiescent on the reused tree.
    let t2 = h.think(sid, 16).unwrap();
    assert!(t2.quiescent);
    let c = h.close(sid).unwrap();
    assert_eq!(c.unobserved, 0);
    assert_eq!(c.thinks, 2);
}

#[test]
fn fair_scheduling_serves_unequal_budgets_concurrently() {
    // A big-budget session must not starve small-budget sessions: open one
    // heavy and several light sessions simultaneously; all must finish.
    let service = SearchService::start(ServiceConfig {
        expansion_workers: 1,
        simulation_workers: 2,
        ..ServiceConfig::default()
    });
    std::thread::scope(|scope| {
        let heavy = service.handle();
        scope.spawn(move || {
            let sid = heavy
                .open(Box::new(garnet(50)), episode_spec(50), SessionOptions::default())
                .unwrap();
            let t = heavy.think(sid, 400).unwrap();
            assert_eq!(t.sims, 400);
            heavy.close(sid).unwrap();
        });
        for i in 0..4 {
            let light = service.handle();
            scope.spawn(move || {
                let seed = 60 + i;
                let sid = light
                    .open(Box::new(garnet(seed)), episode_spec(seed), SessionOptions::default())
                    .unwrap();
                for _ in 0..3 {
                    let t = light.think(sid, 8).unwrap();
                    assert!(t.quiescent);
                }
                light.close(sid).unwrap();
            });
        }
    });
    let m = service.handle().metrics().unwrap();
    assert_eq!(m.sessions_closed, 5);
    assert_eq!(m.sims, 400 + 4 * 3 * 8);
}
