//! Wire-level tests of the binary frame protocol against a live
//! event-loop server.
//!
//! Three claims are proven here, all from outside the process boundary:
//!
//! * **byte cost** — a blob-streamed export puts at most 1.05× the
//!   image's own bytes on the wire (the hex line protocol pays 2×);
//! * **no ceiling** — an image larger than the line protocol's
//!   [`MAX_IMAGE_BYTES`] hex cap round-trips through import and export
//!   as chunked blob frames;
//! * **damage tolerance** — every class of malformed frame (junk bytes,
//!   torn prefix, oversized length, checksum flip, future version, blob
//!   protocol violations) earns a typed `frame_error` reply on the same
//!   connection, which keeps serving afterwards.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use wu_uct::env::garnet::Garnet;
use wu_uct::env::Env;
use wu_uct::mcts::SearchSpec;
use wu_uct::service::frame::{
    encode_frame, FrameStream, MAGIC, MAX_FRAME_PAYLOAD, OP_BLOB_CHUNK, OP_BLOB_END, OP_REQ,
    TRAILER_BYTES, VERSION,
};
use wu_uct::service::json::Json;
use wu_uct::service::proto::MAX_IMAGE_BYTES;
use wu_uct::service::{HostClient, SearchService, ServiceConfig, SessionOptions, TcpServer};
use wu_uct::store::{SessionImage, SessionMeta};
use wu_uct::tree::Tree;

fn start() -> (SearchService, TcpServer) {
    let svc = SearchService::start(ServiceConfig {
        expansion_workers: 1,
        simulation_workers: 2,
        ..Default::default()
    });
    let server = TcpServer::bind(svc.handle(), "127.0.0.1:0").unwrap();
    (svc, server)
}

#[test]
fn binary_export_wire_cost_is_within_five_percent_of_image_bytes() {
    let (_svc, server) = start();
    let client = HostClient::new(server.local_addr().to_string());
    let spec = SearchSpec { max_simulations: 600, rollout_limit: 8, ..SearchSpec::default() };
    let opts = SessionOptions { env_seed: 5, ..SessionOptions::default() };
    let sid = client.open_with_id(1, "garnet", &spec, &opts).unwrap();
    client.think(sid, 600).unwrap();

    let image = client.export(sid).unwrap();
    let img = SessionImage::decode(&image).expect("exported bytes decode as a session image");
    assert_eq!(img.session, sid);
    assert!(img.tree.len() > 100, "600 sims should grow a real tree, got {}", img.tree.len());

    // Everything framed this client ever received is the one export
    // blob: BEGIN header + chunks + END. The hex line protocol would
    // have shipped 2× the image bytes before JSON quoting.
    let (_sent, received) = client.frame_wire_bytes();
    assert!(received >= image.len() as u64, "wire bytes cannot undercut the payload");
    assert!(
        received as f64 <= image.len() as f64 * 1.05,
        "export put {received} bytes on the wire for a {} byte image (> 1.05x)",
        image.len()
    );
}

/// Build a quiescent garnet session image whose encoded size exceeds
/// `target_bytes`, by growing a fanout-3 tree of state-bearing nodes. A
/// leaf node encodes to ~74 bytes, so the node count is sized off that
/// floor and the result lands comfortably past the target.
fn big_image(session: u64, target_bytes: usize) -> SessionImage {
    let env = Garnet::new(15, 3, 30, 0.0, 11);
    let state = env.snapshot();
    let mut tree = Tree::new();
    tree.node_mut(Tree::ROOT).state = Some(state.clone());
    let need = target_bytes / 74 + 1;
    let mut count = 1usize;
    let mut frontier = vec![Tree::ROOT];
    'grow: loop {
        let mut next = Vec::with_capacity(frontier.len() * 3);
        for &p in &frontier {
            for a in 0..3usize {
                let c = tree.add_child(p, a);
                tree.node_mut(c).state = Some(state.clone());
                next.push(c);
                count += 1;
                if count >= need {
                    break 'grow;
                }
            }
        }
        frontier = next;
    }
    SessionImage {
        session,
        env_name: "garnet".to_string(),
        env_state: state,
        spec: SearchSpec::default(),
        rng_state: (0x853c_49e6_748f_ea9b, 0xda3e_39cb_94b9_5bdb),
        meta: SessionMeta { env_seed: 11, ..SessionMeta::default() },
        tree,
    }
}

#[test]
fn blob_streaming_round_trips_an_image_past_the_hex_ceiling() {
    let (_svc, server) = start();
    let client = HostClient::new(server.local_addr().to_string());

    let img = big_image(4242, MAX_IMAGE_BYTES + (1 << 20));
    let encoded = img.encode().expect("crafted image encodes");
    assert!(
        encoded.len() > MAX_IMAGE_BYTES,
        "test image must exceed the {MAX_IMAGE_BYTES} byte hex-line ceiling, got {}",
        encoded.len()
    );

    let (sent0, recv0) = client.frame_wire_bytes();
    let sid = client.import(&encoded).expect("oversized image imports over blob frames");
    assert_eq!(sid, 4242);
    let (sent1, _) = client.frame_wire_bytes();
    let sent = sent1 - sent0;
    assert!(sent >= encoded.len() as u64);
    assert!(
        sent as f64 <= encoded.len() as f64 * 1.05,
        "import put {sent} bytes on the wire for a {} byte image (> 1.05x)",
        encoded.len()
    );

    // The imported session actually serves: one small think on the
    // 400k-node tree must come back quiescent.
    let think = client.think(sid, 8).expect("imported big session thinks");
    assert!(think.quiescent);
    assert!(think.tree_size >= img.tree.len());

    let back = client.export(sid).expect("oversized image exports over blob frames");
    let (_, recv1) = client.frame_wire_bytes();
    let recv = recv1 - recv0;
    assert!(recv >= back.len() as u64);
    assert!(
        recv as f64 <= back.len() as f64 * 1.05,
        "export put {recv} bytes on the wire for a {} byte image (> 1.05x)",
        back.len()
    );

    let round = SessionImage::decode(&back).expect("re-exported image decodes");
    assert_eq!(round.session, 4242);
    assert_eq!(round.meta.env_seed, 11);
    assert!(round.tree.len() >= img.tree.len(), "no nodes may be lost in transit");
    assert_eq!(round.env_state, img.env_state, "root env position survives the round trip");
}

#[test]
fn framed_export_of_an_unknown_session_is_a_typed_error() {
    let (_svc, server) = start();
    let client = HostClient::new(server.local_addr().to_string());
    let err = client.export(31337).expect_err("no such session");
    assert!(
        format!("{err:#}").contains("unknown session 31337"),
        "error should name the session: {err:#}"
    );
    // The refusal was a reply, not a dropped connection or a poisoned
    // pool: the client keeps working.
    client.ping().unwrap();
}

/// Drive raw bytes at a live server and read its framed replies.
struct RawConn {
    tx: TcpStream,
    rx: FrameStream,
}

impl RawConn {
    fn connect(server: &TcpServer) -> RawConn {
        let tx = TcpStream::connect(server.local_addr()).unwrap();
        tx.set_nodelay(true).unwrap();
        let rx_stream = tx.try_clone().unwrap();
        rx_stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        RawConn { tx, rx: FrameStream::new(rx_stream) }
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.tx.write_all(bytes).unwrap();
        self.tx.flush().unwrap();
    }

    fn recv_json(&mut self) -> Json {
        let line = self.rx.recv_reply().expect("server reply frame");
        Json::parse(&line).expect("server replies are JSON")
    }

    /// Read one reply and assert it is a typed frame error whose message
    /// mentions `needle`.
    fn expect_frame_error(&mut self, needle: &str) {
        let v = self.recv_json();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(
            v.get("frame_error").and_then(|b| b.as_bool()),
            Some(true),
            "wire damage must be distinguishable from op-level errors: {}",
            v.render()
        );
        let msg = v.get("error").and_then(|e| e.as_str()).unwrap_or_default().to_string();
        assert!(msg.contains(needle), "error {msg:?} should mention {needle:?}");
    }
}

#[test]
fn malformed_frames_get_typed_replies_and_the_connection_survives() {
    let (_svc, server) = start();
    let mut conn = RawConn::connect(&server);
    let ping = encode_frame(OP_REQ, br#"{"op":"ping"}"#);

    // Torn length prefix: the header arrives split mid-length-field
    // across two writes. Not an error — the frame reassembles.
    conn.send_raw(&ping[..5]);
    std::thread::sleep(Duration::from_millis(50));
    conn.send_raw(&ping[5..]);
    assert_eq!(conn.recv_json().get("ok").and_then(|b| b.as_bool()), Some(true));

    // Junk bytes before a healthy frame: a typed bad-magic reply names
    // the resync, then the healthy frame is served.
    let mut wire = b"this is not a frame".to_vec();
    wire.extend_from_slice(&ping);
    conn.send_raw(&wire);
    conn.expect_frame_error("bad frame magic");
    assert_eq!(conn.recv_json().get("ok").and_then(|b| b.as_bool()), Some(true));

    // Checksum flip: the frame is skipped whole, and named as such.
    let mut flipped = ping.clone();
    *flipped.last_mut().unwrap() ^= 0x01;
    conn.send_raw(&flipped);
    conn.expect_frame_error("checksum mismatch");

    // Future protocol version (checked before the checksum, so no
    // trailer recompute is needed to isolate the fault).
    let mut future = ping.clone();
    future[1] = VERSION + 9;
    conn.send_raw(&future);
    conn.expect_frame_error("unsupported frame version");

    // Oversized length prefix: the error reply arrives as soon as the
    // header parses; the advertised span then streams through and is
    // discarded without ever being buffered server-side.
    let len = (MAX_FRAME_PAYLOAD + 1) as u32;
    let mut oversized = vec![MAGIC, VERSION, OP_REQ, 0];
    oversized.extend_from_slice(&len.to_le_bytes());
    conn.send_raw(&oversized);
    conn.expect_frame_error("oversized frame");
    let span = vec![0xAA_u8; len as usize + TRAILER_BYTES];
    conn.send_raw(&span);

    // Blob protocol violations are frame errors too, not hangs.
    conn.send_raw(&encode_frame(OP_BLOB_CHUNK, b"orphan chunk"));
    conn.expect_frame_error("CHUNK without a BEGIN");
    conn.send_raw(&encode_frame(OP_BLOB_END, &0u64.to_le_bytes()));
    conn.expect_frame_error("END without a BEGIN");

    // Unknown op byte.
    conn.send_raw(&encode_frame(0x7f, b""));
    conn.expect_frame_error("unknown frame op");

    // After every class of damage, the same connection still serves a
    // whole framed episode.
    conn.send_raw(&encode_frame(
        OP_REQ,
        br#"{"op":"open","env":"garnet","seed":3,"sims":8,"rollout":6}"#,
    ));
    let open = conn.recv_json();
    assert_eq!(open.get("ok").and_then(|b| b.as_bool()), Some(true));
    let sid = open.get("session").and_then(|s| s.as_u64()).unwrap();
    let think_req = format!(r#"{{"op":"think","session":{sid}}}"#);
    conn.send_raw(&encode_frame(OP_REQ, think_req.as_bytes()));
    let think = conn.recv_json();
    assert_eq!(think.get("quiescent").and_then(|b| b.as_bool()), Some(true));
    let close_req = format!(r#"{{"op":"close","session":{sid}}}"#);
    conn.send_raw(&encode_frame(OP_REQ, close_req.as_bytes()));
    let close = conn.recv_json();
    assert_eq!(close.get("unobserved").and_then(|u| u.as_u64()), Some(0));
}
